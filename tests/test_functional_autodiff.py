"""The transformation-native solver contract (DESIGN.md §5.1):

  * ``factorize``/``solve`` are pure — ``jit(solve)`` and ``vmap(solve)``
    (stacked factorizations, the multi-LHS case) match eager exactly;
  * ``transpose_solve`` solves A^T x = g from the forward factorization;
  * ``jax.grad`` through ``solve`` matches float64 finite differences for
    tridiag + penta, Dirichlet + periodic, on all three backends — with
    cotangents for the vector-valued diagonals AND the rhs;
  * a ``lax.scan`` diffusion time loop over a closed-over factorization is
    bitwise identical to the step-by-step loop while tracing the solve
    exactly once.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import dense_penta, dense_tridiag
from repro.solver import (BandedSystem, factorize, solve, transpose_solve)

N, M = 16, 3


def _tridiag_coeffs(rng):
    a = rng.uniform(-1, 1, N).astype(np.float32)
    c = rng.uniform(-1, 1, N).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    return a, b, c


def _penta_coeffs(rng):
    a = rng.uniform(-1, 1, N).astype(np.float32)
    b = rng.uniform(-1, 1, N).astype(np.float32)
    d = rng.uniform(-1, 1, N).astype(np.float32)
    e = rng.uniform(-1, 1, N).astype(np.float32)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(np.float32)
    return a, b, c, d, e


def _make(bandwidth, rng, periodic, mode="constant", batch=None):
    coeffs = (_tridiag_coeffs if bandwidth == 3 else _penta_coeffs)(rng)
    ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta
    system = ctor(*coeffs, n=N, periodic=periodic, mode=mode, batch=batch)
    dense = dense_tridiag if bandwidth == 3 else dense_penta
    A = np.asarray(dense(*coeffs, periodic=periodic)).astype(np.float64)
    return coeffs, system, A


# ---------------------------------------------------------------------------
# jit / vmap equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas", "sharded"])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_jit_solve_matches_eager(bandwidth, backend):
    rng = np.random.default_rng(bandwidth)
    _, system, _ = _make(bandwidth, rng, periodic=True)
    fact = factorize(system, backend=backend)
    rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
    eager = solve(fact, rhs)
    jitted = jax.jit(solve)(fact, rhs)
    # tight tolerance: jit only re-fuses the O(M) periodic corner correction
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("bandwidth", [3, 5])
def test_vmap_solve_over_stacked_factorizations(bandwidth):
    """The multi-LHS case: one vmap over stacked Factorization leaves."""
    rng = np.random.default_rng(10 + bandwidth)
    facts, rhss, want = [], [], []
    for _ in range(4):
        _, system, _ = _make(bandwidth, rng, periodic=False)
        fact = factorize(system, backend="reference")
        rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
        facts.append(fact)
        rhss.append(rhs)
        want.append(np.asarray(solve(fact, rhs)))
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *facts)
    got = np.asarray(jax.vmap(solve)(stacked, jnp.stack(rhss)))
    np.testing.assert_array_equal(got, np.stack(want))


# ---------------------------------------------------------------------------
# transpose_solve: the adjoint system from the forward factorization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas", "sharded"])
@pytest.mark.parametrize("mode", ["constant", "uniform", "batch"])
@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_transpose_solve_solves_transposed_system(bandwidth, periodic, mode,
                                                  backend):
    if backend == "pallas" and periodic and mode == "batch":
        pytest.skip("no Pallas kernel for periodic per-system-LHS solves")
    rng = np.random.default_rng(bandwidth * 7 + periodic)
    if mode == "uniform":
        one = np.ones(N, np.float32)
        coeffs = ((-0.4 * one, 1.8 * one, -0.4 * one) if bandwidth == 3 else
                  (0.1 * one, -0.4 * one, 1.6 * one, -0.4 * one, 0.1 * one))
        ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta
        system = ctor(*coeffs, n=N, periodic=periodic, mode=mode)
        dense = dense_tridiag if bandwidth == 3 else dense_penta
        A = np.asarray(dense(*coeffs, periodic=periodic)).astype(np.float64)
    else:
        _, system, A = _make(bandwidth, rng, periodic, mode=mode,
                             batch=M if mode == "batch" else None)
    fact = factorize(system, backend=backend)
    g = rng.normal(size=(N, M)).astype(np.float32)
    x = np.asarray(transpose_solve(fact, jnp.asarray(g)))
    np.testing.assert_allclose(A.T @ x, g, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_transpose_solve_matches_transposed_spec(bandwidth, periodic):
    """transpose_solve (same stored factor) == solving system.transposed()
    (an independently factored A^T spec)."""
    rng = np.random.default_rng(bandwidth + 40)
    _, system, _ = _make(bandwidth, rng, periodic)
    g = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
    via_factor = transpose_solve(factorize(system, backend="reference"), g)
    via_spec = solve(factorize(system.transposed(), backend="reference"), g)
    np.testing.assert_allclose(np.asarray(via_factor), np.asarray(via_spec),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# grad vs float64 finite differences (the acceptance criterion)
# ---------------------------------------------------------------------------

def _np_dense(bandwidth, diags, periodic):
    """Dense matrix in PURE numpy float64 — jnp's dense_* oracles run fp32
    and would flatten the 1e-6 finite-difference perturbations."""
    diags = [np.asarray(d, np.float64) for d in diags]
    n = diags[len(diags) // 2].shape[0]
    if bandwidth == 3:
        a, b, c = diags
        A = np.diag(b) + np.diag(a[1:], -1) + np.diag(c[:-1], 1)
        if periodic:
            A[0, n - 1] += a[0]
            A[n - 1, 0] += c[-1]
        return A
    a, b, c, d, e = diags
    A = (np.diag(c) + np.diag(b[1:], -1) + np.diag(a[2:], -2)
         + np.diag(d[:-1], 1) + np.diag(e[:-2], 2))
    if periodic:
        A[0, n - 2] += a[0]; A[0, n - 1] += b[0]
        A[1, n - 1] += a[1]
        A[n - 2, 0] += e[n - 2]
        A[n - 1, 0] += d[n - 1]; A[n - 1, 1] += e[n - 1]
    return A


def _fd_grads(A_of_diags, diags, rhs, w, eps=1e-6):
    """Central finite differences of loss = w . (A(diags)^-1 rhs), float64."""
    def loss(diags64, rhs64):
        return float(w.ravel() @ np.linalg.solve(A_of_diags(diags64),
                                                 rhs64).ravel())

    diags64 = [d.astype(np.float64) for d in diags]
    rhs64 = rhs.astype(np.float64)
    g_diags = []
    for k, dk in enumerate(diags64):
        g = np.zeros_like(dk)
        for i in range(dk.shape[0]):
            up = [d.copy() for d in diags64]
            dn = [d.copy() for d in diags64]
            up[k][i] += eps
            dn[k][i] -= eps
            g[i] = (loss(up, rhs64) - loss(dn, rhs64)) / (2 * eps)
        g_diags.append(g)
    g_rhs = np.zeros_like(rhs64)
    flat = g_rhs.ravel()
    base = rhs64.ravel()
    for i in range(base.size):
        up = base.copy(); up[i] += eps
        dn = base.copy(); dn[i] -= eps
        flat[i] = (loss(diags64, up.reshape(rhs.shape))
                   - loss(diags64, dn.reshape(rhs.shape))) / (2 * eps)
    return g_diags, g_rhs


@pytest.mark.parametrize("backend", ["reference", "pallas", "sharded"])
@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_grad_solve_matches_finite_differences(bandwidth, periodic, backend):
    rng = np.random.default_rng(bandwidth * 31 + periodic)
    coeffs, _, _ = _make(bandwidth, rng, periodic)
    rhs = rng.normal(size=(N, M)).astype(np.float32)
    w = rng.normal(size=(N, M)).astype(np.float32)
    ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta

    def jax_loss(diags, r):
        fact = factorize(ctor(*diags, n=N, periodic=periodic),
                         backend=backend)
        return jnp.vdot(jnp.asarray(w), solve(fact, r))

    g_diags, g_rhs = jax.grad(jax_loss, argnums=(0, 1))(
        tuple(map(jnp.asarray, coeffs)), jnp.asarray(rhs))

    fd_diags, fd_rhs = _fd_grads(
        lambda d64: _np_dense(bandwidth, d64, periodic),
        list(coeffs), rhs, w)

    for got, want in zip(g_diags, fd_diags):
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3,
                                   atol=1e-3)
    np.testing.assert_allclose(np.asarray(g_rhs), fd_rhs, rtol=1e-3,
                               atol=1e-3)


def test_grad_solve_batch_mode_matches_finite_differences():
    """mode='batch' (per-system LHS copies): grads flow to the shared spec."""
    rng = np.random.default_rng(99)
    coeffs, system, _ = _make(3, rng, periodic=False, mode="batch", batch=M)
    rhs = rng.normal(size=(N, M)).astype(np.float32)
    w = rng.normal(size=(N, M)).astype(np.float32)

    def jax_loss(diags, r):
        fact = factorize(BandedSystem.tridiag(*diags, n=N, mode="batch",
                                              batch=M), backend="reference")
        return jnp.vdot(jnp.asarray(w), solve(fact, r))

    g_diags, g_rhs = jax.grad(jax_loss, argnums=(0, 1))(
        tuple(map(jnp.asarray, coeffs)), jnp.asarray(rhs))
    fd_diags, fd_rhs = _fd_grads(
        lambda d64: _np_dense(3, d64, False), list(coeffs), rhs, w)
    for got, want in zip(g_diags, fd_diags):
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3,
                                   atol=1e-3)
    np.testing.assert_allclose(np.asarray(g_rhs), fd_rhs, rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# the scanned time loop: factor once, trace once, bitwise-identical physics
# ---------------------------------------------------------------------------

def test_scan_stepper_bitwise_matches_step_loop_and_traces_once(monkeypatch):
    from repro.pde import DiffusionCN
    from repro.solver import reference as solver_reference

    n, m, steps = 64, 8, 50
    rng = np.random.default_rng(5)
    f0 = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    model = DiffusionCN(n=n, dt=2e-5, backend="reference")

    traces = {"count": 0}
    orig = solver_reference.solve_stored

    def counting(*args, **kw):
        traces["count"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(solver_reference, "solve_stored", counting)

    out_scan = np.asarray(model.run(f0, steps, use_scan=True))
    scan_traces = traces["count"]
    out_loop = np.asarray(model.run(f0, steps, use_scan=False))
    loop_traces = traces["count"] - scan_traces

    # the scan traced the solve exactly once for the whole integration; the
    # step-by-step python loop re-dispatched it every step
    assert scan_traces == 1
    assert loop_traces == steps

    # bitwise-identical trajectory to the pre-refactor execution model: one
    # compiled step applied n_steps times (the eager loop differs only by
    # per-op vs fused rounding, so it gets a tight allclose instead)
    _, step = model.step_fn()
    jitted_step = jax.jit(step)
    f = f0
    for _ in range(steps):
        f = jitted_step(f)
    np.testing.assert_array_equal(out_scan, np.asarray(f))
    np.testing.assert_allclose(out_scan, out_loop, rtol=1e-5, atol=1e-6)


def test_grad_through_scanned_trajectory_matches_python_loop():
    from repro.pde import HyperdiffusionCN

    n, m, steps = 32, 4, 5
    rng = np.random.default_rng(6)
    f0 = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    model = HyperdiffusionCN(n=n, dt=2e-6, backend="reference")

    g_scan = jax.grad(lambda f: model.run(f, steps, use_scan=True).sum())(f0)
    g_loop = jax.grad(lambda f: model.run(f, steps, use_scan=False).sum())(f0)
    np.testing.assert_allclose(np.asarray(g_scan), np.asarray(g_loop),
                               rtol=1e-6, atol=1e-6)
    assert np.isfinite(np.asarray(g_scan)).all()


# ---------------------------------------------------------------------------
# Factorization pytree hygiene + storage accounting
# ---------------------------------------------------------------------------

def test_factorization_meta_is_static_and_hashable():
    rng = np.random.default_rng(7)
    _, system, _ = _make(3, rng, periodic=True)
    fact = factorize(system, backend="reference")
    leaves, treedef = jax.tree_util.tree_flatten(fact)
    assert all(hasattr(l, "dtype") for l in leaves)   # only arrays trace
    hash(treedef)                                     # meta is static aux
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.meta == fact.meta


def test_batch_mode_rhs_width_mismatch_raises():
    """batch mode stores per-system LHS copies: a clear error, not a
    broadcast failure deep inside the sweep (all backends share the check)."""
    from repro.solver import plan
    rng = np.random.default_rng(8)
    coeffs = _tridiag_coeffs(rng)
    system = BandedSystem.tridiag(*coeffs, n=N, mode="batch", batch=M)
    bad = jnp.ones((N, M + 2), jnp.float32)
    with pytest.raises(ValueError, match="built for M="):
        solve(factorize(system, backend="reference"), bad)
    with pytest.raises(ValueError, match="built for M="):
        transpose_solve(factorize(system, backend="reference"), bad)
    with pytest.raises(ValueError, match="built for M="):
        plan(system, backend="sharded").solve(bad)


def test_storage_bytes_itemsize_follows_dtype():
    from repro.solver import plan
    n, m = 64, 32
    p16 = plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=n, dtype=jnp.float16),
               backend="reference")
    out = p16.storage_bytes(rhs_batch=m)
    assert out["rhs_bytes"] == n * m * 2          # fp16, not hardcoded 4
    p32 = plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=n), backend="reference")
    assert p32.storage_bytes(rhs_batch=m)["rhs_bytes"] == n * m * 4
