"""Optimizer, data pipeline, checkpointing, end-to-end loss descent."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import AsyncWriter, latest_step, restore, save
from repro.configs import get_smoke_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.sharding import LogicalRules, ShardingCtx
from repro.train import AdamW, make_train_step, warmup_cosine


def _ctx():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return ShardingCtx(mesh=jax.sharding.Mesh(devs, ("data", "model")),
                       rules=LogicalRules.default())


def test_schedule():
    f = warmup_cosine(1e-3, warmup=10, total=110)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(f(110)) == pytest.approx(1e-4, rel=1e-3)
    assert float(f(5)) == pytest.approx(5e-4, rel=1e-5)


def test_data_determinism_and_sharding():
    ds = SyntheticLM(vocab=97, seq_len=32, global_batch=8, seed=3)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards tile the global batch
    s0 = ds.batch_at(5, shard=(0, 2))
    s1 = ds.batch_at(5, shard=(1, 2))
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # different steps differ
    assert not np.array_equal(ds.batch_at(6)["tokens"], b1["tokens"])


def test_train_loss_descends():
    """A few steps on the structured synthetic stream must reduce loss."""
    cfg = get_smoke_config("mamba2_130m")
    sctx = _ctx()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(3e-3, 5, 200), weight_decay=0.0)
    opt_state = opt.init(params)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0,
                     structure=1.0)
    step_fn = jax.jit(make_train_step(model, sctx, opt))
    losses = []
    for step in range(30):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             ds.batch_at(step),
                                             jnp.int32(step))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_accumulation_matches_large_batch():
    cfg = get_smoke_config("granite_3_8b")
    sctx = _ctx()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt = AdamW(lr=lambda s: 1e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=1)
    batch = ds.batch_at(0)

    s1 = jax.jit(make_train_step(model, sctx, opt, accum=1))
    s4 = jax.jit(make_train_step(model, sctx, opt, accum=4))
    p1, _, m1 = s1(params, opt_state, batch, jnp.int32(0))
    p4, _, m4 = s4(params, opt_state, batch, jnp.int32(0))
    # same data => same mean gradient => same update (fp32 accum, bf16 noise)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": (jnp.ones((4,), jnp.bfloat16), jnp.float32(3.5))}}
    for step in [1, 2, 3, 4]:
        save(d, step, tree, keep_k=2)
    assert latest_step(d) == 4
    assert sorted(x for x in os.listdir(d) if x.startswith("step_")) == \
        ["step_00000003", "step_00000004"]
    got, step = restore(d)
    assert step == 4
    np.testing.assert_array_equal(got["a"], np.arange(6).reshape(2, 3))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"][0], np.float32),
                                  np.ones(4))
    assert float(got["b"]["c"][1]) == 3.5


def test_checkpoint_async_writer(tmp_path):
    d = str(tmp_path / "ckpt")
    w = AsyncWriter()
    w.submit(d, 7, {"x": jnp.full((8,), 2.0)})
    w.flush()
    got, step = restore(d)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["x"]), np.full(8, 2.0))


def test_checkpoint_restore_with_shardings(tmp_path):
    """The elastic path: restore onto explicit (here trivial) shardings."""
    d = str(tmp_path / "ckpt")
    save(d, 1, {"w": jnp.ones((4, 4))})
    sctx = _ctx()
    sh = {"w": sctx.sharding(("embed", "mlp"), (4, 4))}
    got, _ = restore(d, shardings=sh)
    assert got["w"].sharding == sh["w"]
