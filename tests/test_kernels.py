"""Pallas kernels (interpret mode) vs pure-jnp oracles, shape/dtype sweeps."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    penta_factor,
    periodic_thomas_factor,
    thomas_factor,
)
from repro.kernels import (
    fused_cn_step,
    penta_batch,
    penta_constant,
    thomas_batch,
    thomas_constant,
)
from repro.kernels import ref as kref
from repro.kernels.thomas import hbm_traffic_bytes as tri_traffic
from repro.kernels.penta import hbm_traffic_bytes as pen_traffic


def _tridiag(rng, n, dtype):
    a = rng.uniform(-1, 1, n).astype(dtype)
    c = rng.uniform(-1, 1, n).astype(dtype)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(dtype)
    return a, b, c


def _penta(rng, n, dtype):
    a = rng.uniform(-1, 1, n).astype(dtype)
    b = rng.uniform(-1, 1, n).astype(dtype)
    d = rng.uniform(-1, 1, n).astype(dtype)
    e = rng.uniform(-1, 1, n).astype(dtype)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(dtype)
    return a, b, c, d, e


TOL = {np.float32: dict(rtol=2e-5, atol=2e-5), np.float64: dict(rtol=1e-12, atol=1e-12)}


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("n,m,block_m,unroll", [
    (8, 128, 128, 1),
    (64, 256, 128, 1),
    (64, 256, 128, 4),
    (128, 100, 64, 2),     # ragged M -> lane padding
    (33, 512, 256, 1),     # odd N
])
def test_thomas_constant_kernel_vs_ref(dtype, n, m, block_m, unroll):
    rng = np.random.default_rng(n * 7 + m)
    a, b, c = _tridiag(rng, n, dtype)
    d = rng.normal(size=(n, m)).astype(dtype)
    f = thomas_factor(*map(jnp.asarray, (a, b, c)))
    want = np.asarray(kref.thomas_constant_ref(
        jnp.stack([f.a, f.inv_denom, f.c_hat]), jnp.asarray(d)))
    got = np.asarray(thomas_constant(f, jnp.asarray(d), block_m=block_m,
                                     unroll=unroll, interpret=True))
    np.testing.assert_allclose(got, want, **TOL[dtype])


@pytest.mark.parametrize("n,m", [(64, 256), (32, 128)])
def test_thomas_batch_kernel_vs_ref(n, m):
    rng = np.random.default_rng(3)
    a, b, c = _tridiag(rng, n, np.float32)
    ab = np.broadcast_to(a[:, None], (n, m)).copy()
    bb = np.broadcast_to(b[:, None], (n, m)).copy()
    cb = np.broadcast_to(c[:, None], (n, m)).copy()
    # per-system perturbation so each lane truly has a distinct LHS
    ab += rng.uniform(-0.1, 0.1, (n, m)).astype(np.float32)
    d = rng.normal(size=(n, m)).astype(np.float32)
    want = np.asarray(kref.thomas_batch_ref(*map(jnp.asarray, (ab, bb, cb, d))))
    got = np.asarray(thomas_batch(*map(jnp.asarray, (ab, bb, cb, d)),
                                  block_m=128, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("uniform", [False, True])
@pytest.mark.parametrize("n,m,block_m", [(16, 128, 128), (64, 384, 128), (100, 64, 64)])
def test_penta_constant_kernel_vs_ref(uniform, n, m, block_m):
    rng = np.random.default_rng(n + m)
    if uniform:
        sigma = 0.17
        one = np.ones(n, np.float32)
        a, b, c, d, e = (sigma * one, -4 * sigma * one, (1 + 6 * sigma) * one,
                         -4 * sigma * one, sigma * one)
    else:
        a, b, c, d, e = _penta(rng, n, np.float32)
    rhs = rng.normal(size=(n, m)).astype(np.float32)
    f = penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
    want = np.asarray(kref.penta_constant_ref(
        jnp.stack([jnp.broadcast_to(f.eps, f.beta.shape), f.beta, f.inv_alpha,
                   f.gamma, f.delta]), jnp.asarray(rhs)))
    got = np.asarray(penta_constant(f, jnp.asarray(rhs), block_m=block_m,
                                    interpret=True, uniform=uniform))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_penta_batch_kernel_vs_ref():
    rng = np.random.default_rng(9)
    n, m = 48, 256
    a, b, c, d, e = _penta(rng, n, np.float32)
    tile = lambda v: np.broadcast_to(v[:, None], (n, m)).copy()
    ab, bb, cb, db, eb = map(tile, (a, b, c, d, e))
    cb += rng.uniform(0, 0.2, (n, m)).astype(np.float32)  # distinct LHS per lane
    rhs = rng.normal(size=(n, m)).astype(np.float32)
    want = np.asarray(kref.penta_batch_ref(
        *map(jnp.asarray, (ab, bb, cb, db, eb, rhs))))
    got = np.asarray(penta_batch(*map(jnp.asarray, (ab, bb, cb, db, eb, rhs)),
                                 interpret=True))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n,m", [(64, 128), (128, 256)])
def test_fused_cn_kernel_vs_ref(n, m):
    rng = np.random.default_rng(n)
    sigma = 0.23
    a = -sigma * np.ones(n, np.float32)
    b = (1 + 2 * sigma) * np.ones(n, np.float32)
    c = -sigma * np.ones(n, np.float32)
    pf = periodic_thomas_factor(*map(jnp.asarray, (a, b, c)))
    field = rng.normal(size=(n, m)).astype(np.float32)
    want = np.asarray(kref.fused_cn_tridiag_ref(pf, sigma, jnp.asarray(field)))
    got = np.asarray(fused_cn_step(pf, sigma, jnp.asarray(field), interpret=True))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_fused_cn_multi_step_stability():
    """100 fused CN steps of the heat equation stay bounded & decay."""
    n, m = 64, 128
    dx = 1.0 / n
    dt = 0.4 * dx * dx  # sigma < 1/2 not required (CN unconditionally stable)
    sigma = dt / (2 * dx * dx)
    a = -sigma * np.ones(n, np.float32)
    b = (1 + 2 * sigma) * np.ones(n, np.float32)
    c = -sigma * np.ones(n, np.float32)
    pf = periodic_thomas_factor(*map(jnp.asarray, (a, b, c)))
    x = np.linspace(0, 1, n, endpoint=False)
    field = jnp.asarray(np.tile(np.sin(2 * np.pi * x)[:, None], (1, m)).astype(np.float32))
    e0 = float(jnp.sum(field ** 2))
    for _ in range(100):
        field = fused_cn_step(pf, sigma, field, interpret=True)
    e1 = float(jnp.sum(field ** 2))
    assert np.isfinite(e1) and e1 < e0  # diffusion dissipates energy


def test_traffic_accounting_favors_constant():
    """The analytic HBM traffic model behind the paper's speed-up claim."""
    n, m = 1024, 65536
    t = tri_traffic(n, m)
    assert t["constant"] < t["batch"]
    assert t["batch"] / t["constant"] == pytest.approx(5 / 2, rel=0.01)
    p = pen_traffic(n, m)
    assert p["batch"] / p["constant"] == pytest.approx(7 / 2, rel=0.01)
    assert p["uniform"] < p["constant"]


@pytest.mark.parametrize("n,m", [(64, 128), (128, 256), (96, 64)])
def test_fused_cn_penta_kernel_vs_ref(n, m):
    """Fused hyperdiffusion CN step == stencil + periodic penta solve."""
    from repro.core import periodic_penta_factor, periodic_penta_solve
    from repro.kernels import fused_cn_penta_step
    from repro.pde.stencil import cn_rhs_hyperdiffusion

    rng = np.random.default_rng(n)
    sigma = 0.13
    one = np.ones(n, np.float32)
    coef = (sigma * one, -4 * sigma * one, (1 + 6 * sigma) * one,
            -4 * sigma * one, sigma * one)
    pf = periodic_penta_factor(*map(jnp.asarray, coef))
    field = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    want = np.asarray(periodic_penta_solve(
        pf, cn_rhs_hyperdiffusion(field, sigma)))
    got = np.asarray(fused_cn_penta_step(pf, sigma, field, interpret=True))
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_fused_cn_penta_multi_step_decay():
    from repro.core import periodic_penta_factor
    from repro.kernels import fused_cn_penta_step
    n, m = 64, 128
    dx = 1.0 / n
    sigma = 1e-7 / (2 * dx ** 4)
    one = np.ones(n, np.float32)
    pf = periodic_penta_factor(
        jnp.asarray(sigma * one), jnp.asarray(-4 * sigma * one),
        jnp.asarray((1 + 6 * sigma) * one), jnp.asarray(-4 * sigma * one),
        jnp.asarray(sigma * one))
    x = np.arange(n) / n
    f = jnp.asarray(np.tile(np.sin(2 * np.pi * x)[:, None], (1, m)).astype(np.float32))
    e0 = float(jnp.sum(f ** 2))
    for _ in range(50):
        f = fused_cn_penta_step(pf, sigma, f, interpret=True)
    e1 = float(jnp.sum(f ** 2))
    assert np.isfinite(e1) and e1 < e0
