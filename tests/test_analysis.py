"""The static verification layer (``repro.analysis``).

Three properties matter: the checkers run CLEAN on the real registry (the
support matrix ships speclint-verified), the mutation self-test proves
each checker actually fires on its defect class (a linter that never
fires is a no-op), and the AST lint's allowlist marker behaves.
"""

import pytest

from repro.analysis import Finding, run_all, lint, mutation
from repro.analysis import capture, gridcheck, speccheck, tracecheck
from repro.kernels.engine import REGISTRY


# ---------------------------------------------------------------------------
# Clean on the real registry
# ---------------------------------------------------------------------------

def test_speccheck_clean():
    assert speccheck.run() == []


def test_gridcheck_clean():
    assert gridcheck.run() == []


def test_tracecheck_clean():
    assert tracecheck.run() == []


def test_run_all_clean():
    assert run_all() == []


def test_trace_covers_full_registry():
    # every registered spec emits its expected pallas_call count under the
    # capture harness — the checkers cannot silently skip a variant
    for spec in REGISTRY.values():
        records = capture.trace_spec_calls(spec)
        assert len(records) == spec.num_pallas_calls, spec.name


def test_tracecheck_matrix_spans_backends():
    from repro.solver.registry import available_pure_backends
    cases = tracecheck.contract_cases()
    assert {c[0] for c in cases} == set(available_pure_backends())
    assert len(cases) == len(available_pure_backends()) * 2 * 3 * 2


def test_tracecheck_covers_recurrence_family():
    # 2 orders x fwd/rev x zero-carry/seeded
    assert len(tracecheck.recurrence_cases()) == 8


# ---------------------------------------------------------------------------
# Mutation self-test: each seeded defect class is caught
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mutation_results():
    return {r.name: r for r in mutation.self_test()}


@pytest.mark.parametrize("defect", [m[0] for m in mutation._MUTATIONS])
def test_mutation_detected(mutation_results, defect):
    result = mutation_results[defect]
    assert result.detected, f"analyzer missed seeded defect {defect!r}"
    assert result.evidence


def test_mutation_covers_six_classes():
    assert len(mutation._MUTATIONS) >= 6


def test_mutations_fully_reverted():
    # the self-test patches real module state; the registry must check
    # clean again afterwards (mutation_results fixture already ran)
    assert speccheck.run() == []


# ---------------------------------------------------------------------------
# AST lint behaviour
# ---------------------------------------------------------------------------

def test_lint_flags_concretization():
    src = "def f(x):\n    return float(x) + y.item() + np.asarray(z)\n"
    findings = lint.lint_source(src, "probe.py")
    flagged = {f.message.split(" ", 1)[0] for f in findings}
    assert flagged == {"float(...)", ".item()", "np.asarray(...)"}
    assert all(f.subject == "probe.py:2" for f in findings)


def test_lint_allows_literals_and_marker():
    assert lint.lint_source("n = int(3.5)\n") == []
    assert lint.lint_source("n = float(-1)\n") == []
    marked = f"n = int(x)  # {lint.ALLOW_MARKER}\n"
    assert lint.lint_source(marked) == []


def test_lint_clean_on_traced_packages():
    assert lint.run() == []


def test_lint_reports_syntax_error():
    findings = lint.lint_source("def f(:\n", "bad.py")
    assert len(findings) == 1 and "syntax error" in findings[0].message


def test_finding_str():
    f = Finding("speccheck", "penta_constant", "boom")
    assert str(f) == "[speccheck] penta_constant: boom"
