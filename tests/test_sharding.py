"""Logical sharding resolver: divisibility fallbacks and axis-reuse guards."""

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.sharding import LogicalRules, resolve_spec


def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


RULES = LogicalRules.default()


def test_basic_param_spec():
    mesh = _mesh((4, 2), ("data", "model"))
    spec = resolve_spec(("embed", "mlp"), (512, 2048), mesh, RULES)
    assert spec == P("data", "model")


def test_multi_axis_batch_group():
    mesh = _mesh((2, 4, 2), ("pod", "data", "model"))
    spec = resolve_spec(("act_batch", "act_seq", "act_embed"), (64, 128, 256),
                        mesh, RULES)
    assert spec == P(("pod", "data"), None, None)


def test_missing_axis_dropped():
    mesh = _mesh((4, 2), ("data", "model"))  # no "pod"
    spec = resolve_spec(("act_batch", None), (64, 128), mesh, RULES)
    assert spec == P("data", None)


def test_indivisible_falls_back_to_replicated():
    mesh = _mesh((2, 16), ("data", "model"))
    # 24 heads (minitron) % 16 != 0 -> replicated
    spec = resolve_spec(("heads", "head_dim"), (24, 128), mesh, RULES)
    assert spec[0] is None
    # head_dim picks up the model axis instead (fallback chain)
    assert spec[1] == "model"


def test_axis_not_reused_within_tensor():
    mesh = _mesh((2, 4), ("data", "model"))
    # experts grabs "model"; mlp candidates = ["model"] already used -> None
    spec = resolve_spec(("experts", "embed", "expert_mlp"), (8, 512, 1024),
                        mesh, RULES)
    assert spec == P("model", "data", None)


def test_kv_fallback_chain_for_decode_cache():
    mesh = _mesh((4, 16), ("data", "model"))
    # GQA kv=8 cache: kv fails on 16-way axis, kv_seq picks it up
    spec = resolve_spec(("act_batch", "act_kv", "act_kv_seq", "act_head_dim"),
                        (128, 8, 32768, 128), mesh, RULES)
    assert spec == P("data", None, "model", None)
    # MHA kv=16 cache: kv heads shard directly
    spec = resolve_spec(("act_batch", "act_kv", "act_kv_seq", "act_head_dim"),
                        (128, 16, 32768, 128), mesh, RULES)
    assert spec == P("data", "model", None, None)


def test_override():
    mesh = _mesh((4, 2), ("data", "model"))
    rules = RULES.override(act_seq=["model"])  # sequence parallelism on
    spec = resolve_spec(("act_batch", "act_seq", "act_embed"), (32, 1024, 512),
                        mesh, rules)
    assert spec == P("data", "model", None)


def test_size_one_axis_never_assigned():
    mesh = _mesh((1, 2), ("data", "model"))
    spec = resolve_spec(("act_batch", "act_heads"), (7, 16), mesh, RULES)
    assert spec == P(None, "model")  # data axis of size 1 is useless; 7 % 1 irrelevant
