"""Test-session setup.

Force multiple host CPU devices (before jax initialises its backends) so
the `sharded` solver backend is exercised on a real multi-device CPU mesh.
Existing tests build their meshes from `jax.devices()[:1]`, so they are
unaffected.
"""

import os

_FLAG = "--xla_force_host_platform_device_count=4"
_existing = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _existing:
    os.environ["XLA_FLAGS"] = f"{_existing} {_FLAG}".strip()
