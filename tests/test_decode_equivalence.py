"""Teacher-forcing equivalence across ALL families: stepping decode over the
prompt reproduces the prefill logits. This exercises every cache type (KV,
ring-window KV, RG-LRU state, SSD state+conv tails, enc-dec memory, VLM
image KV) end to end."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.sharding import LogicalRules, ShardingCtx

B, T = 2, 12


def _ctx():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return ShardingCtx(mesh=jax.sharding.Mesh(devs, ("data", "model")),
                       rules=LogicalRules.default())


@pytest.mark.parametrize("arch,tol", [
    ("granite_34b", 2e-2),          # dense MQA
    ("dbrx_132b", 5e-2),            # MoE (capacity-ample)
    ("mamba2_130m", 3e-2),          # SSD state + conv tails
    ("recurrentgemma_9b", 3e-2),    # RG-LRU + ring-window attention
    ("seamless_m4t_large_v2", 3e-2),  # enc-dec cross memory
    ("llama_3_2_vision_90b", 3e-2),   # VLM image KV
])
def test_decode_matches_prefill(arch, tol):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # ample capacity so prefill/decode token-drop patterns cannot differ
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    sctx = _ctx()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.vision_dim)) * 0.1,
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)) * 0.1,
            jnp.bfloat16)

    logits_pre, cache_pre = jax.jit(
        lambda p, b: model.prefill(p, b, sctx))(params, batch)

    # fresh cache; for encdec/vlm the cross/image KV must come from prefill
    cache = model.init_cache(B, T)
    if cfg.family == "encdec":
        cache = dict(cache, mem_k=cache_pre["mem_k"], mem_v=cache_pre["mem_v"])
    if cfg.family == "vlm":
        cache = dict(cache, img_k=cache_pre["img_k"], img_v=cache_pre["img_v"])

    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i, sctx))
    out = None
    for t in range(T):
        out, cache = decode(params, cache, toks[:, t], jnp.int32(t))

    a = np.asarray(out, np.float32)
    b = np.asarray(logits_pre, np.float32)
    # compare normalised log-probs (logit offsets cancel)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol * 10)
