"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU; output shapes + no NaNs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model, tree_size
from repro.sharding import LogicalRules, ShardingCtx


def _cpu_ctx():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    return ShardingCtx(mesh=mesh, rules=LogicalRules.default())


B, S = 2, 64


@pytest.fixture(scope="module")
def sctx():
    return _cpu_ctx()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, sctx):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert tree_size(model.param_specs()) > 0

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.vision_dim)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)

    def loss(p):
        l, m = model.loss(p, batch, sctx)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), f"{arch}: loss not finite"
    # gradient flows to at least the embedding and some deep parameter
    gnorms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), f"{arch}: non-finite grad"
    assert sum(g > 0 for g in gnorms) > len(gnorms) // 2, f"{arch}: dead grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch, sctx):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.vision_dim)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)

    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, sctx))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaN"

    # cache from prefill is sized to the prompt; decode continues within it:
    # take a decode step at pos = S-1 (overwrite-style check of the step fn).
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t, i: model.decode(p, c, t, i, sctx))(
        params, cache, tok, jnp.int32(S - 1))
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), f"{arch}: decode NaN"
    # cache structure round-trips
    jax.tree_util.tree_map(lambda a, b: None, cache, cache2)


def test_decode_matches_prefill_dense():
    """Teacher-forcing equivalence: running decode token-by-token reproduces
    the prefill logits (dense family)."""
    cfg = get_smoke_config("granite_3_8b")
    sctx = _cpu_ctx()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

    logits_pre, _ = jax.jit(
        lambda p, b: model.prefill(p, b, sctx))(params, {"tokens": toks})

    cache = model.init_cache(B, T)
    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i, sctx))
    x = None
    for t in range(T):
        x, cache = decode(params, cache, toks[:, t], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(x, np.float32),
                               np.asarray(logits_pre, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_spec_lines():
    """The exact published numbers from the assignment block."""
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (88, 12288, 96, 8, 28672, 32768)
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.vocab) \
        == (61, 7168, 384, 8, 163840)
    c = get_config("llama-3.2-vision-90b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) \
        == (100, 8192, 64, 28672, 128256)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (24, 768, 128, 50280)
    c = get_config("recurrentgemma-9b")
    assert (c.n_layers, c.window, c.block_pattern) == (38, 2048, ("rec", "rec", "attn"))
    c = get_config("granite-34b")
    assert (c.n_layers, c.n_kv_heads, c.d_ff) == (88, 1, 24576)
    c = get_config("dbrx-132b")
    assert (c.n_experts, c.top_k) == (16, 4)
    c = get_config("seamless-m4t-large-v2")
    assert (c.enc_layers, c.dec_layers, c.d_model, c.vocab) == (24, 24, 1024, 256206)
    c = get_config("granite-3-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 4096, 12800, 49155)
    c = get_config("minitron-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) \
        == (32, 3072, 24, 9216, 256000)
