"""Fused single-call streamed sweeps + mixed-precision (bf16) storage.

The fused variants run the forward and backward sweeps of a streamed
solve in ONE ``pallas_call`` over an ascend-then-descend ``2 * num_n``
chunk grid, keeping the factored intermediates in VMEM scratch instead
of round-tripping them through HBM.  Covers:

  * fused == two-call streamed bit-for-bit (same arithmetic, one grid),
    across ragged N/M, tridiag + penta, Dirichlet + periodic, shared +
    batch layouts;
  * bf16 factor/RHS storage: error bounded (<= 1e-2 rel) against an
    fp64 reference, with the output still at the compute dtype;
  * grad parity through the fused path (the adjoint reuses the stored
    factor through the transposed fused sweeps);
  * tuner policy: ``backend="auto"`` picks the fused point when the
    full-N scratch fits the VMEM budget, spills to the two-call pair
    when it does not, and explicit ``fused=True`` forces streaming;
  * the traffic model: fused <= 0.55x the two-call streamed bytes for
    every tridiag/penta streamed mode (tridiag batch lands exactly on
    its resident 5nm floor), and bf16 storage halves the stored-operand
    bytes again.

debug-NaNs coverage of the fused specs rides the registry-driven
``repro.analysis.nansweep`` (every REGISTRY entry, so the 8 fused specs
are swept automatically).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import common as kcommon
from repro.kernels import ops as kops
from repro.kernels.engine import REGISTRY, SweepSpec
from repro.solver import BandedSystem, factorize, solve
from repro.solver import pallas as solver_pallas

#: resident tridiag/penta working sets exceed the 12 MiB budget here, so
#: the auto tuner must stream — and the fused full-N scratch still fits
#: at block_m=128 (16384 * 128 * 4 B = 8 MiB).
HUGE_N = 16384


def _tridiag_coeffs(rng, n, dtype=np.float32):
    a = rng.uniform(-1, 1, n).astype(dtype)
    c = rng.uniform(-1, 1, n).astype(dtype)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(dtype)
    return a, b, c


def _penta_coeffs(rng, n, dtype=np.float32):
    a, b, d, e = (rng.uniform(-1, 1, n).astype(dtype) for _ in range(4))
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(dtype)
    return a, b, c, d, e


def _shared_system(bandwidth, n, periodic=False, dtype=np.float32, seed=3):
    rng = np.random.default_rng(seed)
    if bandwidth == 3:
        return BandedSystem.tridiag(*_tridiag_coeffs(rng, n, dtype),
                                    periodic=periodic)
    return BandedSystem.penta(*_penta_coeffs(rng, n, dtype),
                              periodic=periodic)


# ---------------------------------------------------------------------------
# Fused == two-call streamed, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
@pytest.mark.parametrize("n,m", [(96, 64), (100, 70)])
def test_fused_matches_two_call_bit_exact(bandwidth, periodic, n, m):
    """Fusing moves the inter-sweep intermediates from HBM to VMEM
    scratch; the arithmetic (and therefore every bit) is unchanged."""
    system = _shared_system(bandwidth, n, periodic)
    rng = np.random.default_rng(n + m)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    two = solve(factorize(system, backend="pallas", block_n=32,
                          fused=False), rhs)
    one = solve(factorize(system, backend="pallas", block_n=32,
                          fused=True), rhs)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(two))


@pytest.mark.parametrize("bandwidth", [3, 5])
def test_fused_batch_matches_two_call_bit_exact(bandwidth):
    n, m = 100, 70      # ragged on both axes at (block_n=32, block_m=128)
    rng = np.random.default_rng(bandwidth)
    k = bandwidth - 1
    off = [rng.uniform(-1, 1, (n, m)).astype(np.float32) for _ in range(k)]
    main = sum(np.abs(o) for o in off) + np.float32(k + 1.0)
    diags = (*off[:k // 2], main.astype(np.float32), *off[k // 2:])
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    fn = kops.thomas_batch if bandwidth == 3 else kops.penta_batch
    two = fn(*map(jnp.asarray, diags), rhs, block_m=128, block_n=32,
             fused=False, interpret=True)
    one = fn(*map(jnp.asarray, diags), rhs, block_m=128, block_n=32,
             fused=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(two))


def test_fused_transposed_matches_two_call_bit_exact():
    n, m = 96, 40
    system = _shared_system(5, n)
    fact = factorize(system, backend="pallas", block_n=32)
    rng = np.random.default_rng(9)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    runs = [solver_pallas.tuned_solve_stored(
        5, "constant", False, fact.stored, rhs, block_m=128, block_n=32,
        interpret=True, fused=fused, transposed=True) for fused in (False,
                                                                    True)]
    np.testing.assert_array_equal(np.asarray(runs[1]), np.asarray(runs[0]))


# ---------------------------------------------------------------------------
# bf16 storage precision
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bandwidth", [3, 5])
def test_bf16_storage_error_bounded_vs_fp64(bandwidth):
    """Stored factor + streamed RHS live at bf16 in HBM; the carries stay
    fp32 in-kernel, so the solve tracks an fp64 reference to <= 1e-2
    relative — bf16's ~3 significant digits, not a runaway recurrence."""
    n, m = 256, 48
    rng = np.random.default_rng(17)
    coeffs = (_tridiag_coeffs(rng, n, np.float64) if bandwidth == 3
              else _penta_coeffs(rng, n, np.float64))
    ctor = (BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta)
    rhs64 = rng.normal(size=(n, m))
    want = solve(factorize(ctor(*coeffs, dtype=jnp.float64),
                           backend="reference"),
                 jnp.asarray(rhs64, jnp.float64))

    sys32 = ctor(*(c.astype(np.float32) for c in coeffs))
    fact = factorize(sys32, backend="pallas", block_n=64,
                     storage_dtype="bf16")
    assert fact.meta.opt("storage_dtype") == "bfloat16"
    got = solve(fact, jnp.asarray(rhs64, jnp.float32))
    assert got.dtype == jnp.float32            # compute dtype, not bf16
    rel = (np.linalg.norm(np.asarray(got, np.float64) - np.asarray(want))
           / np.linalg.norm(np.asarray(want)))
    assert rel <= 1e-2, rel
    # and bf16 storage genuinely degrades vs fp32 storage only modestly
    plain = solve(factorize(sys32, backend="pallas", block_n=64),
                  jnp.asarray(rhs64, jnp.float32))
    assert np.isfinite(np.asarray(plain)).all()


def test_bad_storage_dtype_rejected():
    system = _shared_system(3, 64)
    with pytest.raises(ValueError, match="floating"):
        factorize(system, backend="pallas", storage_dtype="int8")


# ---------------------------------------------------------------------------
# Autodiff through the fused path
# ---------------------------------------------------------------------------

def test_grad_parity_through_fused():
    """The adjoint of a fused streamed solve reuses the same stored factor
    (transposed fused sweeps) and matches the reference gradient."""
    n, m = 192, 32
    system = _shared_system(3, n, seed=21)
    rng = np.random.default_rng(22)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    loss = lambda f, r: jnp.sum(solve(f, r) ** 2)
    g_f = jax.grad(loss, argnums=1)(
        factorize(system, backend="pallas", block_n=32, fused=True), rhs)
    g_r = jax.grad(loss, argnums=1)(
        factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_r),
                               rtol=1e-4, atol=1e-4)
    # fused vs two-call adjoints are the same arithmetic: bit-exact
    g_t = jax.grad(loss, argnums=1)(
        factorize(system, backend="pallas", block_n=32, fused=False), rhs)
    np.testing.assert_array_equal(np.asarray(g_f), np.asarray(g_t))


# ---------------------------------------------------------------------------
# Tuner policy: fused preferred when it fits, graceful spill when not
# ---------------------------------------------------------------------------

def test_auto_picks_fused_at_huge_n_shared():
    """At HUGE_N the resident path is over budget at every block_m; the
    auto tuner must land on the fused streamed point (block_m=128 is the
    only tile whose full-N scratch fits 12 MiB)."""
    for bandwidth in (3, 5):
        system = _shared_system(bandwidth, HUGE_N)
        fact = factorize(system, backend="auto")
        assert fact.backend == "pallas"
        assert fact.meta.opt("fused") is True
        assert fact.meta.opt("block_m") == 128
        assert fact.meta.opt("block_n") is not None


def test_auto_spills_fused_to_two_call_for_batch_at_huge_n():
    """The batch fused working set carries two full-N sweep scratches —
    over budget at HUGE_N — so the tuner must keep the two-call pair
    rather than reject the solve."""
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=HUGE_N,
                                  mode="batch", batch=256)
    bm, bn = solver_pallas.auto_tune(system)
    assert bn is not None
    assert solver_pallas.resolve_fused(system, bm, bn, fused=None) is False


def test_explicit_fused_forces_streaming():
    """fused=True at a resident-fitting N must stream (a fused kernel has
    no resident form) instead of silently dropping the request."""
    system = _shared_system(3, 256)
    assert solver_pallas.auto_tune(system) == (1024, None)   # resident fits
    fact = factorize(system, backend="pallas", fused=True)
    assert fact.meta.opt("fused") is True
    assert fact.meta.opt("block_n") is not None
    rng = np.random.default_rng(1)
    rhs = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    want = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(solve(fact, rhs)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_prefetch_knob_recorded_and_harmless():
    """prefetch=True (the default through factorize) doubles the modelled
    chunk residency for double-buffered DMA; in interpret mode it must
    not change the answer, only the recorded plan."""
    system = _shared_system(3, 100)
    rng = np.random.default_rng(2)
    rhs = jnp.asarray(rng.normal(size=(100, 24)).astype(np.float32))
    on = factorize(system, backend="pallas", block_n=32, prefetch=True)
    off = factorize(system, backend="pallas", block_n=32, prefetch=False)
    assert on.meta.opt("prefetch") is True
    assert off.meta.opt("prefetch") is False
    np.testing.assert_array_equal(np.asarray(solve(on, rhs)),
                                  np.asarray(solve(off, rhs)))


# ---------------------------------------------------------------------------
# The traffic model: the halving claims, recounted from the spec table
# ---------------------------------------------------------------------------

def test_fused_traffic_at_most_055x_two_call():
    """The acceptance ratio: one pallas_call kills the inter-sweep HBM
    round trip, so fused bytes <= 0.55x the two-call streamed bytes for
    every tridiag/penta streamed mode.  The one boundary case — tridiag
    batch at 5/9 ~ 0.556 — lands exactly on its resident 5nm floor (you
    cannot touch fewer words than the resident kernel does)."""
    n, m = HUGE_N, 4096
    fused_specs = [s for s in REGISTRY.values()
                   if isinstance(s, SweepSpec) and s.fused]
    assert len(fused_specs) == 8
    for spec in fused_specs:
        fused_b = spec.traffic_bytes(n, m, jnp.float32)
        two_b = REGISTRY[spec.unfused_name].traffic_bytes(n, m, jnp.float32)
        resident_b = REGISTRY[spec.resident_name].traffic_bytes(
            n, m, jnp.float32)
        assert spec.num_pallas_calls == 1
        if fused_b > 0.55 * two_b:
            # only the tridiag batch boundary case may exceed the ratio,
            # and only by sitting exactly on the resident floor
            assert spec.name == "thomas_batch_streamed_fused"
            assert fused_b == resident_b == 4 * 5 * n * m
        else:
            assert fused_b <= 0.55 * two_b
        assert fused_b >= resident_b       # never below the floor


def test_bf16_storage_halves_stored_operand_bytes():
    """Per-operand pricing: stored words at 2 B, compute words at 4 B —
    so bf16 storage removes exactly half the stored-operand traffic."""
    n, m = HUGE_N, 4096
    bf16 = jnp.dtype(jnp.bfloat16)
    for name in ("thomas_constant_streamed", "thomas_constant_streamed_fused",
                 "penta_constant_streamed_fused", "thomas_batch_streamed"):
        spec = REGISTRY[name]
        sw = spec.storage_words(n, m)
        cw = spec.compute_words(n, m)
        full = spec.traffic_bytes(n, m, jnp.float32)
        mixed = spec.traffic_bytes(n, m, jnp.float32, bf16)
        assert full == 4 * (sw + cw)
        assert mixed == 2 * sw + 4 * cw
        assert full - mixed == 2 * sw      # the stored half, exactly
    # the ops-layer resolver prices the same way
    assert kops.solver_hbm_traffic_bytes(
        3, "constant", n, m, streamed=True, fused=True,
        storage_dtype="bf16") == REGISTRY[
            "thomas_constant_streamed_fused"].traffic_bytes(
                n, m, jnp.float32, bf16)


def test_fused_vmem_model_gates_the_tuner():
    """The spill rule is the VMEM model, not a special case: the shared
    fused scratch fits at block_m=128 and not at 1024 at HUGE_N."""
    system = _shared_system(3, HUGE_N)
    assert solver_pallas._fused_fits(system, 128, 1024)
    assert not solver_pallas._fused_fits(system, 1024, 512)
    ws = kcommon.fused_vmem_working_set(HUGE_N, 1024, 128, 2, 1, 1, 1,
                                        itemsize=4, compute_itemsize=4)
    assert ws <= kcommon.VMEM_BUDGET_BYTES
