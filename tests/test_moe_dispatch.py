"""MoE dispatch modes agree when capacity is ample (no token drops)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.moe import moe_apply, moe_specs
from repro.models.params import init_params
from repro.sharding import LogicalRules, ShardingCtx


def _ctx():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return ShardingCtx(mesh=jax.sharding.Mesh(devs, ("data", "model")),
                       rules=LogicalRules.default())


def test_local_dispatch_matches_global_when_no_drops():
    cfg = get_smoke_config("dbrx_132b")
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    sctx = _ctx()
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32)
                    ).astype(jnp.bfloat16)

    out_g, aux_g = moe_apply(p, x, sctx, cfg)
    cfg_l = dataclasses.replace(cfg, moe_dispatch="local")
    out_l, aux_l = moe_apply(p, x, sctx, cfg_l)
    np.testing.assert_allclose(np.asarray(out_g, np.float32),
                               np.asarray(out_l, np.float32),
                               rtol=5e-2, atol=5e-3)
    np.testing.assert_allclose(float(aux_g["lb_loss"]), float(aux_l["lb_loss"]),
                               rtol=1e-5)


def test_local_dispatch_trains():
    cfg = get_smoke_config("kimi_k2_1t_a32b")
    cfg = dataclasses.replace(cfg, moe_dispatch="local")
    sctx = _ctx()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
    }
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss(p, batch, sctx)[0]))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) > 0
             for g in jax.tree_util.tree_leaves(grads))
    assert gn > 0
