"""Fault-tolerance runtime: compression, straggler, elastic, pipeline.

Multi-device behaviours (pipeline, compressed mean, sharded solve) run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 so the
main test process keeps its single-device view.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.runtime import (
    StragglerMonitor,
    dequantize_int8,
    ef_compress,
    quantize_int8,
    remesh_plan,
    with_retries,
    bubble_fraction,
)


def _run_subprocess(code: str):
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/root"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env,
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With EF, the *accumulated* quantized signal tracks the true signal."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    e = jnp.zeros((64,), jnp.float32)
    acc_q = np.zeros(64)
    for _ in range(50):
        q, s, e = ef_compress(x, e)
        acc_q += np.asarray(dequantize_int8(q, s))
    np.testing.assert_allclose(acc_q / 50, np.asarray(x), atol=1e-3)


def test_straggler_monitor_flags_persistent_slow_host():
    mon = StragglerMonitor(threshold=1.4, patience=3)
    flagged = []
    for step in range(10):
        times = {0: 1.0, 1: 1.02, 2: 0.98, 3: 2.5}   # host 3 is slow
        flagged = mon.update(times)
    assert flagged == [3]
    # a transient blip never gets flagged
    mon2 = StragglerMonitor(threshold=1.4, patience=3)
    for step in range(10):
        times = {0: 1.0, 1: 1.0, 2: 3.0 if step == 4 else 1.0}
        out = mon2.update(times)
    assert out == []


def test_with_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, max_retries=5, backoff_s=0.0)() == "ok"
    assert calls["n"] == 3

    def always_fails():
        raise RuntimeError("permanent")
    with pytest.raises(RuntimeError):
        with_retries(always_fails, max_retries=2, backoff_s=0.0)()


def test_remesh_plan():
    p = remesh_plan(512, model=16)
    assert p.shape == (32, 16) and p.n_used == 512
    p = remesh_plan(500, model=16)         # lost 12 devices
    assert p.shape == (31, 16) and p.n_used == 496
    assert p.utilization > 0.99
    p = remesh_plan(7, model=16)           # catastrophic loss
    assert p.n_used == 4


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)


def test_compressed_mean_multidevice():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime import make_compressed_mean, init_error_state
        mesh = jax.make_mesh((8,), ("pod",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
        err = init_error_state(g)
        mean_c = make_compressed_mean(mesh, "pod")
        out, err2 = jax.jit(mean_c)(g, err)
        want = np.mean(np.asarray(g), axis=0)
        got = np.asarray(out)[0]
        np.testing.assert_allclose(got, want, atol=0.05)
        for r in range(1, 8):
            np.testing.assert_allclose(np.asarray(out)[r], got, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_multidevice_matches_sequential():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime import pipeline_run
        K, M, mb, d = 8, 16, 4, 16
        mesh = jax.make_mesh((K,), ("pp",))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(K, d, d)).astype(np.float32) / np.sqrt(d))
        x = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))
        stage = lambda W, h: jnp.tanh(h @ W)
        got = pipeline_run(mesh, "pp", stage, Ws, x)
        # sequential oracle
        h = x
        for k in range(K):
            h = jnp.tanh(h @ Ws[k])
        np.testing.assert_allclose(np.asarray(got), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_solver_multidevice():
    """The paper's batch solve distributed over 8 devices: one LHS copy per
    device, systems sharded, no result drift."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import thomas_factor, thomas_solve
        mesh = jax.make_mesh((8,), ("batch",))
        rng = np.random.default_rng(0)
        n, m = 64, 512
        a = rng.uniform(-1, 1, n).astype(np.float32)
        c = rng.uniform(-1, 1, n).astype(np.float32)
        b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
        d = rng.normal(size=(n, m)).astype(np.float32)
        f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
        solve = shard_map(lambda fac, dd: thomas_solve(fac, dd),
                          mesh=mesh, in_specs=(P(), P(None, "batch")),
                          out_specs=P(None, "batch"))
        got = jax.jit(solve)(f, jnp.asarray(d))
        want = thomas_solve(f, jnp.asarray(d))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out
