"""The HLO collective-bytes parser: trip-count correction on real compiled
modules (the §Roofline methodology's measured leg)."""


import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import collective_bytes, roofline_terms


def test_trip_count_scales_loop_collectives():
    """A psum inside a lax.scan must be counted trip-count times."""
    if jax.device_count() < 1:
        return
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("d",))

    def f(x):
        def body(c, _):
            s = jax.lax.psum(c, "d")
            return c + 0.001 * s, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    fn = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())
    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    n_ar_static = coll["counts"]["all-reduce"]
    n_ar_dynamic = coll["dynamic_counts"]["all-reduce"]
    if n_ar_static:  # single-device psum may fold away entirely
        assert n_ar_dynamic >= 7 * 1.0 or n_ar_dynamic == n_ar_static


def test_parser_on_synthetic_hlo():
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,4]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,4]{1,0} all-reduce(%x), channel_id=1, to_apply=%add.0
  ROOT %t = (s32[], f32[8,4]) tuple(%i, %ar)
}

%cond.1 (p2: (s32[], f32[8,4])) -> pred[] {
  %p2 = (s32[], f32[8,4]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[8,4]) -> f32[8,4] {
  %a = f32[8,4]{1,0} parameter(0)
  %init = (s32[], f32[8,4]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8,4]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[16,4]{1,0} all-gather(%a), dimensions={0}
  ROOT %out = f32[8,4]{1,0} get-tuple-element(%w), index=1
}
"""
    coll = collective_bytes(hlo)
    # all-reduce: 8*4*4 bytes, in a 5-trip while -> x5
    assert coll["by_kind"]["all-reduce"] == 8 * 4 * 4 * 5
    assert coll["dynamic_counts"]["all-reduce"] == 5
    # all-gather at top level: operand is f32[8,4] -> 128 bytes, x1
    assert coll["by_kind"]["all-gather"] == 8 * 4 * 4
    assert coll["total_bytes"] == 8 * 4 * 4 * 6


def test_roofline_terms_dominance():
    r = roofline_terms(197e12, 100e9, 1e9)       # 1 s compute, .12 s mem
    assert r["dominant"] == "compute"
    assert abs(r["compute_s"] - 1.0) < 1e-9
    r = roofline_terms(1e12, 819e9, 500e9)       # 10 s collective
    assert r["dominant"] == "collective"
    assert abs(r["collective_s"] - 10.0) < 1e-9
