"""Regression: SSD gradients stay finite at realistic sequence lengths.

The masked-exp overflow (EXPERIMENTS.md §Paper-validation debug note) only
manifests when the within-chunk cumulative decay range is large — i.e. at
real chunk sizes with trained-scale dt — so this test uses the full
mamba2-130m chunk size and a long sequence."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def test_ssd_grads_finite_long_sequence():
    rng = np.random.default_rng(0)
    B, S, H, P, N = 1, 512, 4, 16, 16
    chunk = 64
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    # large dt values -> large |cum| range within a chunk (the failure mode)
    dt = jnp.asarray(rng.uniform(0.5, 3.0, size=(B, S, H)).astype(np.float32))
    A_log = jnp.zeros((H,), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))

    def loss(xh, dt, Bm, Cm):
        y, state = ssd_chunked(xh, dt, A_log, Bm, Cm, chunk)
        return jnp.sum(y.astype(jnp.float32) ** 2) + jnp.sum(state ** 2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2, 3))(xh, dt, Bm, Cm)
    assert np.isfinite(float(val))
    for g in grads:
        assert np.isfinite(np.asarray(g, np.float32)).all()
