"""The sharded x streamed composition (DESIGN.md §7): the ``sharded``
backend dispatching the sweep engine's Pallas kernels per device inside
``shard_map``.

Asserted here, on the conftest's 4-device host CPU mesh (interpret-mode
kernels):

  * every supported (bandwidth, boundary, mode) combination runs the
    ENGINE kernels per shard (``meta kernels == "pallas"``) and is
    BIT-EXACT vs the single-device pallas backend in resident mode — the
    per-lane sweep arithmetic is independent of how M was partitioned;
  * at N large enough that no resident tile fits, the per-device tuner
    falls through to the streamed split-N pair and parity holds vs both
    the single-device pallas backend and the float reference (≤ 1e-5);
  * ``grad`` through ``shard_map`` reuses the stored factor on the
    engine's TRANSPOSED kernels (the reference transpose is poisoned);
  * the per-device tuner sizes ``block_m`` against the LOCAL lane count
    and prefers resident whenever the local shard fits the VMEM budget;
  * solves cross ``jit`` and ``lax.scan`` with the mesh frozen in meta.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import common as kcommon
from repro.solver import (BandedSystem, factorize, plan, solve,
                          transpose_solve)
from repro.solver import sharded as solver_sharded

N_SMALL = 64
N_BIG = 12288          # past the resident VMEM wall at every block_m
M = 24                 # deliberately lane-tile-ragged and mesh-divisible


def _coeffs(bandwidth, n, uniform, seed=0):
    rng = np.random.default_rng(seed + bandwidth)
    if bandwidth == 3:
        if uniform:
            s, one = 0.37, np.ones(n, np.float32)
            return -s * one, (1 + 2 * s) * one, -s * one
        a = rng.uniform(-1, 1, n).astype(np.float32)
        c = rng.uniform(-1, 1, n).astype(np.float32)
        return a, (np.abs(a) + np.abs(c) + 2.5).astype(np.float32), c
    if uniform:
        s, one = 0.11, np.ones(n, np.float32)
        return s * one, -4 * s * one, (1 + 6 * s) * one, -4 * s * one, s * one
    a, b, d, e = (rng.uniform(-1, 1, n).astype(np.float32) for _ in range(4))
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(np.float32)
    return a, b, c, d, e


def _system(bandwidth, n, periodic, mode, m=M):
    ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta
    return ctor(*_coeffs(bandwidth, n, uniform=(mode == "uniform")), n=n,
                periodic=periodic, mode=mode,
                batch=m if mode == "batch" else None)


def _rhs(n, m, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))


def test_mesh_is_multi_device():
    assert jax.device_count() >= 4, "conftest should force 4 host devices"


@pytest.mark.parametrize("mode", ["constant", "uniform", "batch"])
@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_resident_sharded_kernels_bitexact_vs_pallas(bandwidth, periodic,
                                                     mode):
    """Supported modes run the engine's kernels per shard and match the
    single-device pallas backend BIT-exactly in resident mode (and the
    reference sweeps to fp32 tolerance); periodic x batch degrades to
    reference sweeps per shard instead of raising."""
    system = _system(bandwidth, N_SMALL, periodic, mode)
    rhs = _rhs(N_SMALL, M)
    fact = factorize(system, backend="sharded")
    x = solve(fact, rhs)

    if periodic and mode == "batch":
        assert fact.meta.opt("kernels") == "reference"
    else:
        assert fact.meta.opt("kernels") == "pallas"
        assert fact.meta.opt("block_n") is None, "resident expected at N=64"
        x_pallas = solve(factorize(system, backend="pallas"), rhs)
        assert jnp.array_equal(x, x_pallas), \
            "sharded kernel dispatch must be bit-exact vs single-device pallas"
    x_ref = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["constant", "uniform", "batch"])
@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_streamed_sharded_kernels_parity_large_n(bandwidth, periodic, mode):
    """N past the resident wall: the per-device tuner falls through to the
    HBM-streamed split-N pair inside shard_map; parity vs the single-device
    streamed pallas backend and vs the reference sweeps (<= 1e-5)."""
    if periodic and mode == "batch":
        pytest.skip("no Pallas kernel for periodic per-system-LHS solves")
    m = 8                                  # keep interpret-mode cost down
    system = _system(bandwidth, N_BIG, periodic, mode, m=m)
    rhs = _rhs(N_BIG, m)
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("kernels") == "pallas"
    assert fact.meta.opt("block_n") is not None, \
        "expected the streamed kernels past the VMEM wall"
    x = jax.jit(solve)(fact, rhs)

    fact_p = factorize(system, backend="pallas")
    assert fact_p.meta.opt("block_n") is not None
    x_pallas = solve(fact_p, rhs)
    if periodic:
        # the kernel output is bit-identical; the O(M) corner-correction
        # epilogue runs outside the kernel, where XLA may fuse differently
        # inside shard_map — last-ulp noise, far inside the 1e-5 criterion
        np.testing.assert_allclose(np.asarray(x), np.asarray(x_pallas),
                                   rtol=1e-6, atol=1e-7)
    else:
        np.testing.assert_array_equal(np.asarray(x), np.asarray(x_pallas))

    x_ref = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-5)


def test_grad_through_shard_map_reuses_stored_factor(monkeypatch):
    """grad(solve) on the sharded backend runs the engine's TRANSPOSED
    kernels per shard on the SAME stored factor — the reference transpose
    sweeps are poisoned to prove they are never consulted."""
    system = _system(3, N_BIG, True, "constant", m=8)
    rhs = _rhs(N_BIG, 8)
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("kernels") == "pallas"
    assert fact.meta.opt("block_n") is not None

    def _poisoned(*a, **k):
        raise AssertionError("sharded adjoint fell back to reference sweeps")

    monkeypatch.setattr(solver_sharded, "transpose_solve_stored", _poisoned)
    g = jax.grad(lambda r: jnp.sum(solve(fact, r) ** 2))(rhs)

    fact_p = factorize(system, backend="pallas")
    g_pallas = jax.grad(lambda r: jnp.sum(solve(fact_p, r) ** 2))(rhs)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_pallas))

    # the adjoint entry point distributes too (same poison still in place)
    lam = transpose_solve(fact, rhs)
    lam_p = transpose_solve(fact_p, rhs)
    np.testing.assert_array_equal(np.asarray(lam), np.asarray(lam_p))


def test_grad_flows_to_diagonals_through_mesh():
    """Diagonal cotangents (the PDE-constrained-optimisation carriers) agree
    with the reference backend through the shard_map dispatch."""
    n, m = 256, 16
    coeffs = _coeffs(3, n, uniform=False)
    rhs = _rhs(n, m)

    def loss(backend):
        def f(diags):
            system = BandedSystem.tridiag(*diags, n=n)
            return jnp.sum(solve(factorize(system, backend=backend), rhs) ** 2)
        return f

    diags = tuple(map(jnp.asarray, coeffs))
    g_sh = jax.grad(loss("sharded"))(diags)
    g_ref = jax.grad(loss("reference"))(diags)
    for gs, gr in zip(g_sh, g_ref):
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gr),
                                   rtol=2e-4, atol=2e-4)


def test_local_tuner_sizes_block_m_to_the_shard():
    """batch mode, M=512 over 4 devices: the single-device tuner would pick
    block_m=512 (the global lane count), but each shard only holds 128
    lanes — the per-device tuner must size to the LOCAL slice."""
    from repro.solver import pallas as solver_pallas
    system = _system(3, N_SMALL, False, "batch", m=512)
    assert solver_pallas.auto_tune(system) == (512, None)
    tuned = solver_sharded.local_tune(system, n_shards=4)
    assert tuned == (128, None), "tuner must see the local lane count"
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("block_m") == 128
    assert fact.meta.opt("block_n") is None


def test_local_tuner_prefers_resident_when_local_shard_fits(monkeypatch):
    """Resident is preferred whenever the local working set fits the
    budget; squeezing the budget flips the same system to streamed."""
    system = _system(3, 2048, False, "constant")
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("kernels") == "pallas"
    assert fact.meta.opt("block_n") is None, \
        "per-device auto-tune must pick resident when the shard fits"
    # resident at N=2048 needs >= (2*2048*128 + 3*2048)*4 ~ 2.1 MB even at
    # the smallest lane tile, but a (256, 256) streamed chunk holds ~0.5 MB
    # -> under a 1 MB budget the tuner must fall through to streamed
    monkeypatch.setattr(kcommon, "VMEM_BUDGET_BYTES", 1_000_000)
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("kernels") == "pallas"
    assert fact.meta.opt("block_n") is not None


def test_kernels_policy_knob():
    """kernels="reference" keeps the scan sweeps; kernels="pallas" raises
    for unsupported modes instead of silently degrading."""
    system = _system(3, N_SMALL, False, "constant")
    fact = factorize(system, backend="sharded", kernels="reference")
    assert fact.meta.opt("kernels") == "reference"
    assert fact.meta.opt("block_m") is None
    x = solve(fact, _rhs(N_SMALL, M))
    x_ref = solve(factorize(system, backend="reference"), _rhs(N_SMALL, M))
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-5)

    with pytest.raises(ValueError, match="kernels must be one of"):
        factorize(system, backend="sharded", kernels="nope")

    # the policy binds the stored layout + tuned blocks at factorize time:
    # flipping it per call via with_options must fail loudly, both ways
    from repro.solver import with_options
    with pytest.raises(ValueError, match="resolved at factorize time"):
        solve(with_options(fact, kernels="pallas"), _rhs(N_SMALL, M))
    # overriding block_m alongside kernels must not slip past the guard
    with pytest.raises(ValueError, match="resolved at factorize time"):
        solve(with_options(fact, kernels="pallas", block_m=128),
              _rhs(N_SMALL, M))
    fact_k = factorize(system, backend="sharded")   # kernels resolved: pallas
    with pytest.raises(ValueError, match="resolved at factorize time"):
        solve(with_options(fact_k, kernels="reference"), _rhs(N_SMALL, M))

    periodic_batch = _system(3, N_SMALL, True, "batch")
    with pytest.raises(NotImplementedError, match="cannot run the engine"):
        factorize(periodic_batch, backend="sharded", kernels="pallas")
    # auto degrades per-shard instead
    assert factorize(periodic_batch,
                     backend="sharded").meta.opt("kernels") == "reference"


def test_sharded_kernels_inside_lax_scan():
    """Factor once, scan a CN loop: the mesh rides the static meta, so the
    shard_map dispatch traces exactly once inside one compiled program."""
    sigma = 0.4
    system = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=N_SMALL,
                                  periodic=True)
    fact = factorize(system, backend="sharded")
    assert fact.meta.opt("kernels") == "pallas"
    field0 = _rhs(N_SMALL, M)

    def body(field, _):
        lap = jnp.roll(field, 1, 0) - 2 * field + jnp.roll(field, -1, 0)
        return solve(fact, field + sigma * lap), None

    scanned, _ = jax.lax.scan(body, field0, None, length=3)

    fact_p = factorize(system, backend="pallas")

    def body_p(field, _):
        lap = jnp.roll(field, 1, 0) - 2 * field + jnp.roll(field, -1, 0)
        return solve(fact_p, field + sigma * lap), None

    want, _ = jax.lax.scan(body_p, field0, None, length=3)
    np.testing.assert_array_equal(np.asarray(scanned), np.asarray(want))


def test_plan_frontend_exposes_tuned_shard_meta():
    """plan(system, backend="sharded") surfaces the resolved per-shard
    tuning (the acceptance-criterion spelling)."""
    p = plan(_system(3, N_SMALL, False, "constant"), backend="sharded")
    assert p.backend == "sharded"
    assert p.impl.kernels == "pallas"
    assert p.impl.n_shards == jax.device_count()
    assert p.impl.block_m is not None
    x = p.solve(_rhs(N_SMALL, M))
    assert x.shape == (N_SMALL, M)


def test_sharded_traffic_model_derives_from_spec():
    """The sharded x streamed roofline entry is the per-device slice of the
    single-device spec model — LHS stream replicated, RHS terms sharded."""
    from repro.kernels.engine import find_spec
    from repro.kernels.ops import (sharded_solver_hbm_traffic_bytes,
                                   solver_hbm_traffic_bytes)
    n, m, shards = 4096, 1024, 4
    for mode, streamed in (("constant", False), ("constant", True),
                           ("uniform", True), ("batch", True)):
        per_dev = sharded_solver_hbm_traffic_bytes(5, mode, n, m, shards,
                                                   streamed=streamed)
        spec = find_spec(5, mode, streamed=streamed)
        assert per_dev == spec.traffic_words(n, m // shards) * 4
        single = solver_hbm_traffic_bytes(5, mode, n, m, streamed=streamed)
        assert per_dev < single
    # transposed batch adjoints reuse the forward batch kernels
    assert (sharded_solver_hbm_traffic_bytes(3, "batch", n, m, shards,
                                             transposed=True)
            == sharded_solver_hbm_traffic_bytes(3, "batch", n, m, shards))
    # the per-device LHS stream does NOT shrink with the mesh
    spec = find_spec(3, "constant")
    words = spec.sharded_traffic_words(n, m, shards)
    assert words == 2 * n * (m // shards) + 3 * n
