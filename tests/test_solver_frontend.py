"""The unified repro.solver front-end: backend parity (reference vs
pallas-interpret vs sharded CPU mesh), auto-selection fallback, block_m
auto-tuning, and the registry contract."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    penta_factor,
    penta_solve,
    periodic_penta_factor,
    periodic_penta_solve,
    periodic_thomas_factor,
    periodic_thomas_solve,
    thomas_factor,
    thomas_solve,
)
from repro.kernels import common as kcommon
from repro.solver import BandedSystem, Plan, available_backends, plan
from repro.solver import pallas as solver_pallas
from repro.solver import registry as solver_registry

N, M = 64, 96


def _tridiag_coeffs(rng, n, uniform):
    if uniform:
        s = 0.37
        one = np.ones(n, np.float32)
        return -s * one, (1 + 2 * s) * one, -s * one
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    return a, b, c


def _penta_coeffs(rng, n, uniform):
    if uniform:
        s = 0.11
        one = np.ones(n, np.float32)
        return s * one, -4 * s * one, (1 + 6 * s) * one, -4 * s * one, s * one
    a = rng.uniform(-1, 1, n).astype(np.float32)
    b = rng.uniform(-1, 1, n).astype(np.float32)
    d = rng.uniform(-1, 1, n).astype(np.float32)
    e = rng.uniform(-1, 1, n).astype(np.float32)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(np.float32)
    return a, b, c, d, e


def _core_reference(bandwidth, periodic, coeffs, rhs):
    """The pre-existing repro.core solve the front-end must reproduce."""
    coeffs = tuple(map(jnp.asarray, coeffs))
    if bandwidth == 3:
        if periodic:
            return periodic_thomas_solve(periodic_thomas_factor(*coeffs), rhs)
        return thomas_solve(thomas_factor(*coeffs), rhs)
    if periodic:
        return periodic_penta_solve(periodic_penta_factor(*coeffs), rhs)
    return penta_solve(penta_factor(*coeffs), rhs)


def _system(bandwidth, coeffs, periodic, mode, batch):
    ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta
    return ctor(*coeffs, n=N, periodic=periodic, mode=mode,
                batch=batch if mode == "batch" else None)


@pytest.mark.parametrize("backend", ["reference", "pallas", "sharded"])
@pytest.mark.parametrize("mode", ["constant", "uniform", "batch"])
@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_backend_parity(bandwidth, periodic, mode, backend):
    """Every (bandwidth, periodic, mode, backend) combination matches the
    repro.core thomas_solve / penta_solve references to <= 1e-5."""
    if backend == "pallas" and periodic and mode == "batch":
        pytest.skip("no Pallas kernel for periodic per-system-LHS solves")
    rng = np.random.default_rng(bandwidth * 100 + periodic * 10)
    make = _tridiag_coeffs if bandwidth == 3 else _penta_coeffs
    coeffs = make(rng, N, uniform=(mode == "uniform"))
    rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))

    p = plan(_system(bandwidth, coeffs, periodic, mode, M), backend=backend)
    assert p.backend == backend
    want = np.asarray(_core_reference(bandwidth, periodic, coeffs, rhs))
    got = np.asarray(p.solve(rhs))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas", "sharded"])
def test_single_rhs_shape_preserved(backend):
    rng = np.random.default_rng(0)
    coeffs = _tridiag_coeffs(rng, N, uniform=False)
    d = jnp.asarray(rng.normal(size=N).astype(np.float32))
    p = plan(_system(3, coeffs, False, "constant", None), backend=backend)
    x = p.solve(d)
    assert x.shape == (N,)
    want = np.asarray(_core_reference(3, False, coeffs, d))
    np.testing.assert_allclose(np.asarray(x), want, rtol=1e-5, atol=1e-5)


def test_sharded_uses_cpu_mesh_and_pads_ragged_batch():
    assert jax.device_count() >= 2, "conftest should force >=2 host devices"
    rng = np.random.default_rng(1)
    coeffs = _tridiag_coeffs(rng, N, uniform=False)
    p = plan(_system(3, coeffs, True, "constant", None), backend="sharded")
    assert p.impl.n_shards == jax.device_count()
    # M = 97 is not divisible by the mesh -> exercises identity-lane padding
    rhs = jnp.asarray(rng.normal(size=(N, 97)).astype(np.float32))
    want = np.asarray(_core_reference(3, True, coeffs, rhs))
    np.testing.assert_allclose(np.asarray(p.solve(rhs)), want,
                               rtol=1e-5, atol=1e-5)


def test_auto_prefers_pallas_when_it_fits():
    rng = np.random.default_rng(2)
    coeffs = _tridiag_coeffs(rng, N, uniform=False)
    p = plan(_system(3, coeffs, False, "constant", None), backend="auto")
    assert p.backend == "pallas"


def test_auto_falls_back_to_reference_when_vmem_would_trip(monkeypatch):
    """backend='auto' must degrade to reference instead of raising when
    check_vmem would reject even the smallest block_m."""
    rng = np.random.default_rng(3)
    coeffs = _tridiag_coeffs(rng, N, uniform=False)
    system = _system(3, coeffs, False, "constant", None)
    monkeypatch.setattr(kcommon, "VMEM_BUDGET_BYTES", 1024)
    p = plan(system, backend="auto")
    assert p.backend == "reference"
    rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
    want = np.asarray(_core_reference(3, False, coeffs, rhs))
    np.testing.assert_allclose(np.asarray(p.solve(rhs)), want,
                               rtol=1e-5, atol=1e-5)
    # explicit pallas still raises (the user asked for it, so no fallback)
    with pytest.raises(NotImplementedError):
        plan(system, backend="pallas")


def test_auto_falls_back_for_periodic_batch_mode():
    rng = np.random.default_rng(4)
    coeffs = _tridiag_coeffs(rng, N, uniform=False)
    p = plan(_system(3, coeffs, True, "batch", M), backend="auto")
    assert p.backend == "reference"


def test_block_m_autotunes_against_vmem_budget(monkeypatch):
    rng = np.random.default_rng(5)
    coeffs = _tridiag_coeffs(rng, 256, uniform=False)
    system = BandedSystem.tridiag(*coeffs, n=256)
    # plenty of budget -> largest candidate
    assert solver_pallas.auto_block_m(system) == 1024
    # (2*256*bm + 3*256)*4 bytes: 600 kB fits bm=256, not bm=512
    monkeypatch.setattr(kcommon, "VMEM_BUDGET_BYTES", 600_000)
    assert solver_pallas.auto_block_m(system) == 256
    p = plan(system, backend="pallas")
    assert p.impl.block_m == 256


def test_registry_contract():
    assert {"reference", "pallas", "sharded"} <= set(available_backends())
    with pytest.raises(KeyError, match="unknown solver backend"):
        plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=8), backend="nope")

    @solver_registry.register_backend("_test_echo")
    class EchoBackend:
        def __init__(self, system, **opts):
            self.system = system
            self.stored = ()

        def solve(self, rhs, **kw):
            return rhs

    try:
        p = plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=8),
                 backend="_test_echo")
        assert isinstance(p, Plan)
        rhs = jnp.ones((8, 2))
        assert p.solve(rhs) is rhs
    finally:
        solver_registry._REGISTRY.pop("_test_echo", None)


def test_plan_storage_bytes_matches_paper_accounting():
    n, m = 1024, 4096
    const = plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=n), backend="reference")
    batch = plan(BandedSystem.tridiag(1.0, 4.0, 1.0, n=n, mode="batch",
                                      batch=m), backend="reference")
    tot_c = const.storage_bytes(rhs_batch=m)["total_bytes"]
    tot_b = batch.storage_bytes(rhs_batch=m)["total_bytes"]
    assert tot_c == (3 * n + n * m) * 4
    assert tot_b == (4 * n * m) * 4
    assert 1 - tot_c / tot_b > 0.74


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_operator_shims_match_frontend():
    """TridiagOperator/PentaOperator keep their call signatures and now run
    through the same engine as the front-end."""
    from repro.core import PentaOperator, TridiagOperator

    rng = np.random.default_rng(6)
    a, b, c = _tridiag_coeffs(rng, N, uniform=False)
    d = jnp.asarray(rng.normal(size=(N, 7)).astype(np.float32))
    op = TridiagOperator.create(a, b, c, mode="constant", periodic=True)
    p = plan(BandedSystem.tridiag(a, b, c, periodic=True), backend="reference")
    np.testing.assert_allclose(np.asarray(op.solve(d, method="scan", unroll=1)),
                               np.asarray(p.solve(d)), rtol=1e-6, atol=1e-6)

    pa, pb, pc_, pd_, pe = _penta_coeffs(rng, N, uniform=True)
    op5 = PentaOperator.create(pa, pb, pc_, pd_, pe, mode="uniform",
                               periodic=True)
    p5 = plan(BandedSystem.penta(pa, pb, pc_, pd_, pe, periodic=True,
                                 mode="uniform"), backend="reference")
    np.testing.assert_allclose(np.asarray(op5.solve(d)),
                               np.asarray(p5.solve(d)), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["pallas", "sharded"])
def test_pde_layer_flips_backends(backend):
    """DiffusionCN routed through repro.solver: one argument flips backends."""
    from repro.pde import DiffusionCN

    n, m = 64, 32
    dt, steps = 2e-5, 3
    rng = np.random.default_rng(7)
    f0 = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    ref = DiffusionCN(n=n, dt=dt, backend="reference")
    other = DiffusionCN(n=n, dt=dt, backend=backend)
    a = np.asarray(ref.run(f0, steps, use_scan=False))
    b = np.asarray(other.run(f0, steps, use_scan=False))
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)
