"""Registry-driven sanitizer sweep (``repro.analysis.nansweep``).

One parametrized case per registered spec so a dead-lane NaN regression
names its variant directly; CI's nan-guard job additionally runs the same
sweep via ``python -m repro.analysis --nan-sweep`` under
``JAX_DEBUG_NANS=1``.
"""

import numpy as np
import pytest

import jax

from repro.analysis import nansweep
from repro.kernels.engine import REGISTRY


@pytest.fixture(autouse=True)
def _debug_nans():
    was = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", True)
    yield
    jax.config.update("jax_debug_nans", was)


@pytest.mark.parametrize("name", sorted(REGISTRY))
@pytest.mark.parametrize("case", [c[0] for c in nansweep.CASES])
def test_spec_finite(name, case):
    spec = REGISTRY[name]
    case_name, n, m, block_m, block_n = next(
        c for c in nansweep.CASES if c[0] == case)
    rng = np.random.default_rng(7)
    x = nansweep._dispatch(spec, rng, n, m, block_m, block_n)
    vals = np.asarray(x)
    assert vals.shape == (n, m)
    assert np.isfinite(vals).all(), (
        f"{int((~np.isfinite(vals)).sum())} non-finite values in "
        f"{name}[{case_name}]")


def test_sweep_runs_clean():
    assert nansweep.run() == []
