"""The paper's benchmark problems converge to their analytic solutions, and
all backends (core jnp / pallas pipeline / fused kernel) agree."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.pde import ADI2D, DiffusionCN, HyperdiffusionCN


def test_diffusion_matches_analytic_decay():
    n, m = 128, 8
    dt, steps = 1e-5, 400
    model = DiffusionCN(n=n, dt=dt, backend="core")
    x = np.arange(n) / n
    f0 = np.tile(np.sin(2 * np.pi * x)[:, None], (1, m)).astype(np.float32)
    out = np.asarray(model.run(jnp.asarray(f0), steps))
    want = model.analytic(x, dt * steps)[:, None]
    np.testing.assert_allclose(out, np.tile(want, (1, m)), rtol=2e-3, atol=2e-4)


def test_diffusion_backends_agree():
    n, m = 64, 128
    dt, steps = 2e-5, 25
    x = np.arange(n) / n
    rng = np.random.default_rng(0)
    f0 = (np.sin(2 * np.pi * x)[:, None]
          + 0.3 * rng.normal(size=(n, m))).astype(np.float32)
    outs = {}
    for backend in ["core", "pallas", "fused"]:
        model = DiffusionCN(n=n, dt=dt, backend=backend)
        outs[backend] = np.asarray(model.run(jnp.asarray(f0), steps,
                                             use_scan=False))
    np.testing.assert_allclose(outs["core"], outs["pallas"], rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(outs["core"], outs["fused"], rtol=3e-4, atol=3e-5)


def test_hyperdiffusion_matches_analytic_decay():
    n, m = 64, 4
    dt, steps = 2e-6, 300
    model = HyperdiffusionCN(n=n, dt=dt, backend="core", mode="constant")
    x = np.arange(n) / n
    f0 = np.tile(np.sin(2 * np.pi * x)[:, None], (1, m)).astype(np.float32)
    out = np.asarray(model.run(jnp.asarray(f0), steps))
    want = model.analytic(x, dt * steps)[:, None]
    np.testing.assert_allclose(out, np.tile(want, (1, m)), rtol=1.5e-2, atol=1e-3)


@pytest.mark.parametrize("mode", ["constant", "uniform"])
def test_hyperdiffusion_backends_agree(mode):
    n, m = 64, 128
    dt, steps = 2e-6, 10
    x = np.arange(n) / n
    rng = np.random.default_rng(1)
    f0 = (np.sin(4 * np.pi * x)[:, None]
          + 0.2 * rng.normal(size=(n, m))).astype(np.float32)
    core = HyperdiffusionCN(n=n, dt=dt, backend="core", mode=mode)
    pal = HyperdiffusionCN(n=n, dt=dt, backend="pallas", mode=mode)
    a = np.asarray(core.run(jnp.asarray(f0), steps, use_scan=False))
    b = np.asarray(pal.run(jnp.asarray(f0), steps, use_scan=False))
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-5)


def test_hyperdiffusion_baseline_mode_agrees():
    """cuPentBatch-equivalent (per-system LHS) gives the same physics."""
    n, m = 48, 16
    dt, steps = 2e-6, 5
    rng = np.random.default_rng(2)
    f0 = rng.normal(size=(n, m)).astype(np.float32)
    const = HyperdiffusionCN(n=n, dt=dt, mode="constant")
    batch = HyperdiffusionCN(n=n, dt=dt, mode="batch", batch=m)
    a = np.asarray(const.run(jnp.asarray(f0), steps, use_scan=False))
    b = np.asarray(batch.run(jnp.asarray(f0), steps, use_scan=False))
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-5)


def test_adi2d_matches_analytic_decay():
    nx = ny = 48
    dt, steps = 1e-4, 60
    model = ADI2D(nx=nx, ny=ny, dt=dt)
    x = (np.arange(nx) / nx)[:, None]
    y = (np.arange(ny) / ny)[None, :]
    f0 = (np.sin(2 * np.pi * x) * np.sin(2 * np.pi * y)).astype(np.float32)
    out = np.asarray(model.run(jnp.asarray(f0), steps))
    want = model.analytic(x, y, dt * steps).astype(np.float32)
    np.testing.assert_allclose(out, want, rtol=5e-3, atol=5e-4)


def test_adi2d_batched_fields():
    nx, ny, b = 32, 32, 3
    model = ADI2D(nx=nx, ny=ny, dt=1e-4)
    rng = np.random.default_rng(3)
    f0 = rng.normal(size=(nx, ny, b)).astype(np.float32)
    out = np.asarray(model.run(jnp.asarray(f0), 10))
    assert out.shape == (nx, ny, b)
    assert np.isfinite(out).all()
    # each batch member evolves exactly as if solo
    solo = np.asarray(model.run(jnp.asarray(f0[..., 0]), 10))
    np.testing.assert_allclose(out[..., 0], solo, rtol=1e-5, atol=1e-6)
