"""Boundary behaviour of the VMEM budget checks and padding helpers
(``repro.kernels.common``) — exactly-at-budget must pass, one byte over
must raise, and aligned padding must be an identity (no copy)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.common import (VMEM_BUDGET_BYTES, check_vmem,
                                  check_vmem_streamed, pad_lanes,
                                  pad_sweep, pad_to_multiple, shard_lanes,
                                  streamed_vmem_working_set,
                                  vmem_working_set)


# ---------------------------------------------------------------------------
# Budget boundaries (itemsize=1 makes the working set exactly countable)
# ---------------------------------------------------------------------------

def test_resident_budget_exactly_at():
    # ws = (1 * 1 * (B - 4) + 4 * 1) * 1 == VMEM_BUDGET_BYTES
    block_m = VMEM_BUDGET_BYTES - 4
    assert vmem_working_set(1, block_m, 1, 4, itemsize=1) == \
        VMEM_BUDGET_BYTES
    check_vmem(1, block_m, n_rhs_blocks=1, n_lhs_vecs=4, itemsize=1)


def test_resident_budget_one_byte_over():
    block_m = VMEM_BUDGET_BYTES - 4
    with pytest.raises(ValueError, match="exceeds VMEM budget"):
        check_vmem(1, block_m, n_rhs_blocks=1, n_lhs_vecs=5, itemsize=1)


def test_streamed_budget_exactly_at():
    # ws = (1 * 1 * (B - 7) + 3 * 1 + 4 * (B - 7)) ... keep it simple:
    # block_n = block_m = 1 -> ws = n_rhs + n_lhs + n_carry
    n_rhs = VMEM_BUDGET_BYTES - 7
    assert streamed_vmem_working_set(1, 1, n_rhs, 3, 4, itemsize=1) == \
        VMEM_BUDGET_BYTES
    check_vmem_streamed(1, 1, n_rhs_blocks=n_rhs, n_lhs_vecs=3, n_carry=4,
                        itemsize=1)


def test_streamed_budget_one_byte_over():
    n_rhs = VMEM_BUDGET_BYTES - 7
    with pytest.raises(ValueError, match="exceeds VMEM"):
        check_vmem_streamed(1, 1, n_rhs_blocks=n_rhs, n_lhs_vecs=3,
                            n_carry=5, itemsize=1)


def test_budget_scales_with_itemsize():
    # the float64 working set is twice the float32 one — the checks must
    # use the caller's itemsize, not assume 4 bytes
    assert vmem_working_set(8, 16, 2, 3, itemsize=8) == \
        2 * vmem_working_set(8, 16, 2, 3, itemsize=4)


# ---------------------------------------------------------------------------
# Padding identities
# ---------------------------------------------------------------------------

def test_pad_to_multiple_aligned_is_identity():
    x = jnp.ones((6, 8))
    padded, size = pad_to_multiple(x, 4, axis=1)
    assert padded is x and size == 8


def test_pad_sweep_aligned_is_identity():
    x = jnp.ones((16, 5))
    padded, size = pad_sweep(x, 8, axis=0)
    assert padded is x and size == 16


def test_pad_lanes_aligned_is_identity():
    x = jnp.ones((5, 64))
    padded, m = pad_lanes(x, 64)
    assert padded is x and m == 64


def test_pad_lanes_identity_value():
    x = jnp.ones((2, 3))
    padded, m = pad_lanes(x, 8, identity=True)
    assert m == 3 and padded.shape == (2, 8)
    assert np.array_equal(np.asarray(padded[:, 3:]), np.ones((2, 5)))
    zero_padded, _ = pad_lanes(x, 8)
    assert np.array_equal(np.asarray(zero_padded[:, 3:]), np.zeros((2, 5)))


def test_pad_sweep_rounds_up():
    x = jnp.ones((9, 2))
    padded, size = pad_sweep(x, 8, axis=0)
    assert padded.shape == (16, 2) and size == 9


# ---------------------------------------------------------------------------
# shard_lanes edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n_shards,want", [
    (0, 4, 0),        # empty batch shards to empty slices
    (7, 1, 7),        # single device: no padding at all
    (8, 4, 2),        # exact split
    (9, 4, 3),        # one straggler pads the whole row up
    (1, 8, 1),        # more devices than systems: one lane each
    (128, 128, 1),
])
def test_shard_lanes(m, n_shards, want):
    assert shard_lanes(m, n_shards) == want
