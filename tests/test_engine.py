"""The declarative sweep engine: registry sanity, a full interpret-mode
parity matrix over EVERY registered ``SweepSpec``, and the spec-derived
traffic / VMEM accounting (no hand-kept tables to drift).

The matrix is the CI job that guards the engine's contract: each variant
(2 bandwidths x shared/batch x fwd/transposed x resident/streamed x
uniform) is exercised through the ``repro.kernels.ops`` dispatch on ragged
shapes and compared against the ``repro.core`` reference sweeps, and each
streamed variant must be BIT-exact against its resident sibling (same
arithmetic, chunked).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (penta_factor, penta_factor_solve, penta_solve,
                        penta_solve_t, thomas_factor, thomas_factor_solve,
                        thomas_solve, thomas_solve_t)
from repro.kernels import ops as kops
from repro.kernels.engine import (REGISTRY, RecurrenceSpec, SweepSpec,
                                  find_recurrence_spec, find_spec)

# ragged on both axes: exercises lane padding and sweep padding
N, M = 45, 70
BLOCK_M, BLOCK_N = 64, 16


def _tridiag_factor(rng):
    a = rng.uniform(-1, 1, N).astype(np.float32)
    c = rng.uniform(-1, 1, N).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    return thomas_factor(*map(jnp.asarray, (a, b, c)))


def _penta_coeffs(rng, uniform):
    if uniform:
        one = np.ones(N, np.float32)
        s = 0.11
        return s * one, -4 * s * one, (1 + 6 * s) * one, -4 * s * one, s * one
    a = rng.uniform(-1, 1, N).astype(np.float32)
    b = rng.uniform(-1, 1, N).astype(np.float32)
    d = rng.uniform(-1, 1, N).astype(np.float32)
    e = rng.uniform(-1, 1, N).astype(np.float32)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(
        np.float32)
    return a, b, c, d, e


def _batch_diags(rng, bandwidth):
    if bandwidth == 3:
        a = rng.uniform(-1, 1, (N, M)).astype(np.float32)
        c = rng.uniform(-1, 1, (N, M)).astype(np.float32)
        b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
        return tuple(map(jnp.asarray, (a, b, c)))
    a, b, d, e = (rng.uniform(-1, 1, (N, M)).astype(np.float32)
                  for _ in range(4))
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(
        np.float32)
    return tuple(map(jnp.asarray, (a, b, c, d, e)))


def _recurrence_gates(rng, order):
    """Stable per-token gate operands (|s| + |t| < 1 bounds the carries)."""
    scales = (0.9,) if order == 1 else (0.6, 0.3)
    return tuple(jnp.asarray(rng.uniform(-s, s, (N, M)).astype(np.float32))
                 for s in scales)


def _recurrence_reference(gates, q, reverse):
    """Token-by-token numpy scan: h_i = q_i + sum_k gate_k[i] * h_{i-k}."""
    gates = [np.asarray(g) for g in gates]
    q = np.asarray(q)
    h = np.zeros_like(q)
    carries = [np.zeros(q.shape[1], q.dtype) for _ in gates]
    for i in (range(N - 1, -1, -1) if reverse else range(N)):
        v = q[i].copy()
        for g, c in zip(gates, carries):
            v += g[i] * c
        h[i] = v
        carries = [v] + carries[:-1]
    return h


def _run_spec(spec, rhs):
    """Dispatch ``rhs`` through the ops layer exactly as the solver backend
    would, returning (got, want) for the parity check."""
    # seed on the streaming-invariant fields so a streamed spec and its
    # resident sibling solve the SAME system (the bit-exactness pairing)
    if isinstance(spec, RecurrenceSpec):
        rng = np.random.default_rng(spec.order * 8 + spec.reverse * 2)
        gates = _recurrence_gates(rng, spec.order)
        got = kops.recurrence(*gates, rhs, reverse=spec.reverse,
                              block_m=BLOCK_M,
                              block_n=BLOCK_N if spec.streamed else None,
                              interpret=True)
        return got, _recurrence_reference(gates, rhs, spec.reverse)
    seed = (spec.bandwidth * 8 + (spec.layout == "batch") * 4
            + spec.transposed * 2 + spec.uniform)
    rng = np.random.default_rng(seed)
    block_n = BLOCK_N if spec.streamed else None
    if spec.layout == "batch":
        diags = _batch_diags(rng, spec.bandwidth)
        fn = kops.thomas_batch if spec.bandwidth == 3 else kops.penta_batch
        got = fn(*diags, rhs, block_m=BLOCK_M, block_n=block_n,
                 fused=getattr(spec, "fused", False), interpret=True)
        oracle = (thomas_factor_solve if spec.bandwidth == 3
                  else penta_factor_solve)
        return got, oracle(*diags, rhs)
    if spec.bandwidth == 3:
        f = _tridiag_factor(rng)
        got = kops.thomas_constant(f, rhs, block_m=BLOCK_M, block_n=block_n,
                                   fused=getattr(spec, "fused", False),
                                   interpret=True, transposed=spec.transposed)
        want = (thomas_solve_t if spec.transposed else thomas_solve)(f, rhs)
        return got, want
    f = penta_factor(*map(jnp.asarray, _penta_coeffs(rng, spec.uniform)))
    got = kops.penta_constant(f, rhs, block_m=BLOCK_M, block_n=block_n,
                              fused=getattr(spec, "fused", False),
                              interpret=True, uniform=spec.uniform,
                              transposed=spec.transposed)
    want = (penta_solve_t if spec.transposed else penta_solve)(f, rhs)
    return got, want


# ---------------------------------------------------------------------------
# Registry shape
# ---------------------------------------------------------------------------

def test_registry_covers_the_variant_matrix():
    """2 bandwidths x (shared: fwd/transposed x resident/streamed/fused
    [x uniform for penta]) + (batch: resident/streamed/fused) = 24 sweep
    specs, plus the gated recurrence family (2 orders x fwd/rev x
    resident/streamed) = 32 specs total."""
    assert len(REGISTRY) == 32
    for order in (1, 2):
        for reverse in (False, True):
            for streamed in (False, True):
                assert RecurrenceSpec(order, reverse=reverse,
                                      streamed=streamed).name in REGISTRY
    for bw in (3, 5):
        for transposed in (False, True):
            for streamed, fused in ((False, False), (True, False),
                                    (True, True)):
                assert SweepSpec(bw, "shared", transposed=transposed,
                                 streamed=streamed,
                                 fused=fused).name in REGISTRY
                if bw == 5:
                    assert SweepSpec(bw, "shared", transposed=transposed,
                                     streamed=streamed, fused=fused,
                                     uniform=True).name in REGISTRY
        for streamed, fused in ((False, False), (True, False), (True, True)):
            assert SweepSpec(bw, "batch", streamed=streamed,
                             fused=fused).name in REGISTRY


def test_no_transposed_batch_spec():
    """Transposed batch solves roll the diagonals and reuse the forward
    batch kernels — the engine refuses to mint a redundant variant."""
    with pytest.raises(ValueError):
        SweepSpec(3, "batch", transposed=True)
    with pytest.raises(ValueError):
        SweepSpec(3, "shared", uniform=True)  # uniform is penta-only


def test_find_spec_maps_tridiag_uniform_to_constant():
    assert find_spec(3, "uniform").name == "thomas_constant"
    assert find_spec(5, "uniform", streamed=True,
                     transposed=True).name == "penta_uniform_streamed_t"


# ---------------------------------------------------------------------------
# The parity matrix: every registered spec vs the reference sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_spec_parity_matrix(name):
    spec = REGISTRY[name]
    rng = np.random.default_rng(7)
    rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
    got, want = _run_spec(spec, rhs)
    assert got.shape == (N, M)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("name", sorted(n for n, s in REGISTRY.items()
                                        if s.streamed))
def test_streamed_specs_bit_exact_vs_resident(name):
    """Chunking changes where the carries live, not the arithmetic."""
    spec = REGISTRY[name]
    resident = REGISTRY[spec.resident_name]
    rng = np.random.default_rng(11)
    rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))
    got, _ = _run_spec(spec, rhs)
    res, _ = _run_spec(resident, rhs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(res))


# ---------------------------------------------------------------------------
# Spec-derived accounting: traffic + VMEM (satellite: every registered
# spec must have a traffic entry — derived, not hand-kept)
# ---------------------------------------------------------------------------

def test_every_registered_spec_has_a_traffic_entry():
    n, m = 512, 1024
    for spec in REGISTRY.values():
        words = spec.traffic_words(n, m)
        assert isinstance(words, int) and words > 0
        assert spec.traffic_bytes(n, m, jnp.float64) == 8 * words
        if isinstance(spec, RecurrenceSpec):
            # single-pass family: streaming revisits nothing, and the ops
            # resolver lands on the same registered spec
            assert words == (spec.order + 2) * n * m
            assert kops.recurrence_hbm_traffic_bytes(
                spec.order, n, m, streamed=spec.streamed,
                reverse=spec.reverse) == spec.traffic_bytes(n, m)
            continue
        if spec.layout == "batch":
            continue
        # the dispatcher resolves the same spec to the same number
        assert kops.solver_hbm_traffic_bytes(
            spec.bandwidth, spec.mode, n, m, streamed=spec.streamed,
            fused=getattr(spec, "fused", False),
            transposed=spec.transposed) == spec.traffic_bytes(n, m)
    # batch entries resolve through the mode path (incl. the rolled adjoint)
    for bw in (3, 5):
        b = kops.solver_hbm_traffic_bytes(bw, "batch", n, m)
        assert kops.solver_hbm_traffic_bytes(bw, "batch", n, m,
                                             transposed=True) == b
        assert kops.solver_hbm_traffic_bytes(
            bw, "batch", n, m, streamed=True) > b


def test_traffic_derivation_matches_paper_numbers():
    """The derived model reproduces the hand-derived paper/PR-3 numbers."""
    n, m = 1024, 4096
    sweeps = [s for s in REGISTRY.values() if isinstance(s, SweepSpec)]
    tri = {s.name: s for s in sweeps if s.bandwidth == 3}
    assert tri["thomas_constant"].traffic_words(n, m) == 2 * n * m + 3 * n
    assert tri["thomas_batch"].traffic_words(n, m) == 5 * n * m
    assert tri["thomas_constant_streamed"].traffic_words(n, m) \
        == 2 * (2 * n * m + 3 * n)
    # batch streamed: 4 in + 2 out (fwd, c_hat spilled) + 2 in + 1 out (bwd)
    assert tri["thomas_batch_streamed"].traffic_words(n, m) == 9 * n * m
    pen = {s.name: s for s in sweeps if s.bandwidth == 5}
    assert pen["penta_uniform"].traffic_words(n, m) == 2 * n * m + 4 * n + 1
    # batch streamed: 6 in + 3 out (fwd, gamma/delta spilled) + 3 in + 1 out
    assert pen["penta_batch_streamed"].traffic_words(n, m) == 13 * n * m
    # transposed twins move the same streams
    for k in ("thomas_constant", "thomas_constant_streamed",
              "penta_uniform"):
        reg = {s.name: s for s in REGISTRY.values()}
        assert reg[k + "_t"].traffic_words(n, m) == reg[k].traffic_words(n, m)


def test_vmem_counts_are_spec_derived():
    """The budget checks reason from the spec's stream structure."""
    assert REGISTRY["thomas_constant"].vmem_counts() == (2, 3, 1)
    assert REGISTRY["penta_constant"].vmem_counts() == (2, 5, 2)
    assert REGISTRY["penta_uniform"].vmem_counts() == (2, 4, 2)
    # batch fwd kernels: diagonals + rhs in, intermediate + coefs out
    assert REGISTRY["thomas_batch"].vmem_counts() == (6, 0, 2)
    assert REGISTRY["penta_batch"].vmem_counts() == (9, 0, 6)
    # transposed shares the forward's working set
    assert REGISTRY["thomas_constant_t"].vmem_counts() \
        == REGISTRY["thomas_constant"].vmem_counts()


def test_find_spec_errors_name_valid_choices():
    """Unknown combos raise informative ValueErrors, never bare KeyErrors
    leaking the internal registry key."""
    with pytest.raises(ValueError, match="bandwidth 3 .* and 5"):
        find_spec(7, "constant")
    with pytest.raises(ValueError, match="'constant'.*'uniform'.*'batch'"):
        find_spec(3, "dense")
    with pytest.raises(ValueError, match="rolls the per-lane diagonals"):
        find_spec(3, "batch", transposed=True)
    # tridiag uniform aliases to the constant kernel (no eps row to drop)
    assert find_spec(3, "uniform").name == "thomas_constant"
    # the recurrence lookup names its valid orders the same way
    with pytest.raises(ValueError, match="order 1 .* and order 2"):
        find_recurrence_spec(3)
    assert find_recurrence_spec(2, reverse=True,
                                streamed=True).name == "recur2_streamed_rev"


def test_traffic_bytes_errors_are_informative():
    with pytest.raises(ValueError, match="bandwidth"):
        kops.solver_hbm_traffic_bytes(4, "constant", 64, 64)
    with pytest.raises(ValueError, match="storage mode"):
        kops.solver_hbm_traffic_bytes(3, "woops", 64, 64)
    # the batch adjoint reuses the forward batch kernels - same streams
    assert kops.solver_hbm_traffic_bytes(3, "batch", 64, 64,
                                         transposed=True) \
        == kops.solver_hbm_traffic_bytes(3, "batch", 64, 64)
