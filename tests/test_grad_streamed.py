"""Large-N adjoints on Pallas + streamed batch mode past the VMEM wall.

The two ROADMAP items the sweep engine closed:

  * ``grad(solve)`` at N >= 12288 (where no resident kernel fits) must run
    the engine's STREAMED TRANSPOSED Pallas kernels — asserted by poisoning
    the reference transposed sweeps — and match a float64 reference
    gradient, for tridiag + penta x dirichlet + periodic.
  * ``mode="batch"`` past the old VMEM wall must stay on the pallas
    backend (the fused factorisation's c_hat / gamma+delta scratch spills
    to HBM between the two passes), bit-exact vs the resident batch kernel
    on ragged N/M and NaN-clean under ``jax_debug_nans``.
"""

import contextlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.solver.reference as solver_reference
from repro.kernels import ops as kops
from repro.solver import BandedSystem, factorize, solve
from repro.solver import pallas as solver_pallas

BIG_N = 12288          # no resident tile fits (see test_streamed_solvers)
BATCH_WALL_N = 8192    # resident batch needs 6*N*128*4 B > the 12 MiB budget


@contextlib.contextmanager
def _no_reference_transpose(monkeypatch):
    """Poison the reference transposed sweeps: any adjoint that falls back
    off Pallas fails loudly instead of silently losing the fast path."""
    def boom(*args, **kwargs):
        raise AssertionError(
            "adjoint fell back to reference.transpose_solve_stored")
    monkeypatch.setattr(solver_reference, "transpose_solve_stored", boom)
    try:
        yield
    finally:
        monkeypatch.undo()


@contextlib.contextmanager
def _debug_nans():
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", False)


def _big_system(bandwidth, periodic, dtype=jnp.float32):
    if bandwidth == 3:
        return BandedSystem.tridiag(-0.4, 1.8, -0.4, n=BIG_N,
                                    periodic=periodic, dtype=dtype)
    return BandedSystem.penta(0.11, -0.44, 1.66, -0.44, 0.11, n=BIG_N,
                              periodic=periodic, dtype=dtype)


def _loss(fact, rhs):
    return jnp.sum(solve(fact, rhs) ** 2)


# ---------------------------------------------------------------------------
# Large-N gradients: streamed transposed Pallas kernels, fp64 parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_large_n_grad_runs_pallas_and_matches_fp64(bandwidth, periodic,
                                                   monkeypatch):
    system = _big_system(bandwidth, periodic)
    fact = factorize(system, backend="auto")
    assert fact.backend == "pallas"
    assert fact.meta.opt("block_n") is not None     # streamed regime

    rng = np.random.default_rng(bandwidth * 2 + periodic)
    rhs32 = jnp.asarray(rng.normal(size=(BIG_N, 8)).astype(np.float32))

    with _no_reference_transpose(monkeypatch):
        g32 = jax.grad(_loss, argnums=1)(fact, rhs32)

    # float64 reference oracle for the same gradient
    jax.config.update("jax_enable_x64", True)
    try:
        sys64 = _big_system(bandwidth, periodic, dtype=jnp.float64)
        fact64 = factorize(sys64, backend="reference")
        rhs64 = jnp.asarray(np.asarray(rhs32, np.float64))
        g64 = jax.grad(_loss, argnums=1)(fact64, rhs64)
        g64 = np.asarray(g64)
    finally:
        jax.config.update("jax_enable_x64", False)

    scale = max(np.abs(g64).max(), 1e-30)
    err = np.abs(np.asarray(g32, np.float64) - g64).max() / scale
    assert err < 2e-4, f"relative grad error {err}"


def test_large_n_diagonal_cotangents_flow_through_pallas(monkeypatch):
    """The dA cotangents (diagonal leaves) also ride the Pallas adjoint."""
    system = _big_system(3, False)
    fact = factorize(system, backend="auto")
    rng = np.random.default_rng(3)
    rhs = jnp.asarray(rng.normal(size=(BIG_N, 4)).astype(np.float32))

    def loss_of_fact(f):
        return _loss(f, rhs)

    with _no_reference_transpose(monkeypatch):
        bar = jax.grad(loss_of_fact)(fact)
    ref = jax.grad(loss_of_fact)(factorize(system, backend="reference"))
    for g_p, g_r in zip(bar.diagonals, ref.diagonals):
        assert np.isfinite(np.asarray(g_p)).all()
        np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_r),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("uniform", [False, True])
def test_uniform_transposed_solve_is_jittable(uniform, monkeypatch):
    """The transposed uniform kernels read eps from the (1, 1) operand —
    jit over a traced Factorization must not concretise it."""
    n, m = 96, 32
    one = np.ones(n, np.float32)
    s = 0.11
    system = BandedSystem.penta(s * one, -4 * s * one, (1 + 6 * s) * one,
                                -4 * s * one, s * one,
                                mode="uniform" if uniform else "constant")
    fact = factorize(system, backend="pallas", block_n=32)
    rng = np.random.default_rng(9)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    with _no_reference_transpose(monkeypatch):
        g = jax.jit(jax.grad(_loss, argnums=1))(fact, rhs)
    g_ref = jax.grad(_loss, argnums=1)(
        factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Streamed batch mode: bit-exact vs resident, past the wall, NaN-clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,block_n,block_m", [
    (64, 128, 16, 128),
    (100, 70, 32, 64),      # ragged N and M -> sweep + lane padding
    (33, 192, 8, 128),      # odd N
])
def test_batch_streamed_matches_resident_bit_exact(n, m, block_n, block_m):
    rng = np.random.default_rng(n * 3 + m)
    a = rng.uniform(-1, 1, (n, m)).astype(np.float32)
    c = rng.uniform(-1, 1, (n, m)).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    d = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    res = kops.thomas_batch(*map(jnp.asarray, (a, b, c)), d,
                            block_m=block_m, interpret=True)
    got = kops.thomas_batch(*map(jnp.asarray, (a, b, c)), d,
                            block_m=block_m, block_n=block_n, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(res))

    pa, pb, pd, pe = (rng.uniform(-1, 1, (n, m)).astype(np.float32)
                      for _ in range(4))
    pc = (np.abs(pa) + np.abs(pb) + np.abs(pd) + np.abs(pe) + 4.0).astype(
        np.float32)
    args = list(map(jnp.asarray, (pa, pb, pc, pd, pe)))
    res5 = kops.penta_batch(*args, d, block_m=block_m, interpret=True)
    got5 = kops.penta_batch(*args, d, block_m=block_m, block_n=block_n,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(got5), np.asarray(res5))


def test_batch_mode_streams_past_the_vmem_wall():
    """The acceptance bar: a batch solve at an N no resident tile holds
    must stay on the pallas backend (streamed) and match reference."""
    m = 130
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=BATCH_WALL_N,
                                  mode="batch", batch=m)
    assert solver_pallas.auto_block_m(system) is None   # resident: no fit
    ok, why = solver_pallas.supports(system)
    assert ok and "streamed" in why

    fact = factorize(system, backend="auto")
    assert fact.backend == "pallas"
    assert fact.meta.opt("block_n") is not None

    rng = np.random.default_rng(1)
    rhs = jnp.asarray(rng.normal(size=(BATCH_WALL_N, m)).astype(np.float32))
    got = jax.jit(solve)(fact, rhs)
    want = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_batch_grad_past_the_wall_stays_on_pallas(monkeypatch):
    """Batch adjoints roll the per-lane diagonals and reuse the forward
    batch kernels — streamed here, and never the reference sweeps."""
    m = 70
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=BATCH_WALL_N,
                                  mode="batch", batch=m)
    fact = factorize(system, backend="auto")
    assert fact.backend == "pallas"
    rng = np.random.default_rng(2)
    rhs = jnp.asarray(rng.normal(size=(BATCH_WALL_N, m)).astype(np.float32))
    with _no_reference_transpose(monkeypatch):
        g = jax.grad(_loss, argnums=1)(fact, rhs)
    g_ref = jax.grad(_loss, argnums=1)(
        factorize(system, backend="reference"), rhs)
    scale = np.abs(np.asarray(g_ref)).max()
    assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() / scale < 1e-4


def test_batch_streamed_is_nan_clean():
    """Identity padding on BOTH axes of the main diagonal: the fused
    factorisation divides in-kernel, so zero-padded sweep rows (and dead
    lanes) would compute 1/0 without it."""
    n, m = 100, 70          # pads N 100 -> 128 at block_n=32, M 70 -> 128
    rng = np.random.default_rng(4)
    a = rng.uniform(-1, 1, (n, m)).astype(np.float32)
    c = rng.uniform(-1, 1, (n, m)).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    d = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    with _debug_nans():
        x = kops.thomas_batch(*map(jnp.asarray, (a, b, c)), d,
                              block_m=128, block_n=32, interpret=True)
    assert np.isfinite(np.asarray(x)).all()


def test_transposed_streamed_is_nan_clean():
    """Sweep-axis zero padding of the SHIFTED coefficient rows stays
    finite under jax_debug_nans (the transposed kernels never divide)."""
    n, m = 100, 70
    rng = np.random.default_rng(5)
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    from repro.core import thomas_factor
    f = thomas_factor(*map(jnp.asarray, (a, b, c)))
    d = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    with _debug_nans():
        x = kops.thomas_constant(f, d, block_m=128, block_n=32,
                                 interpret=True, transposed=True)
    assert np.isfinite(np.asarray(x)).all()
