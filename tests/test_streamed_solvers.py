"""HBM-streamed (split-N) Pallas solvers + the PR-3 bug regressions.

Covers:
  * regression — jitted uniform-mode penta solve on the pallas backend
    (``float(f.eps[2])`` on a traced leaf used to raise
    ``ConcretizationTypeError``), including inside ``lax.scan``;
  * regression — dead padded lanes in the batch-mode kernels factor as
    identity rows, so the whole padded kernel output is finite and the
    solves run clean under ``jax_debug_nans``;
  * streamed kernels == resident kernels bit-for-bit at small N (same
    arithmetic, chunked), across ragged N/M and both bandwidths;
  * streamed solve == reference at an N where the resident ``supports()``
    used to return False, for tridiag + penta, Dirichlet + periodic, under
    jit / vmap / grad (the adjoint reuses the same stored factor);
  * the 2-D ``(block_m, block_n)`` auto-tune policy and the honest
    streamed HBM-traffic model.
"""

import contextlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import common as kcommon
from repro.kernels import ops as kops
from repro.solver import BandedSystem, factorize, plan, solve
from repro.solver import pallas as solver_pallas

# the smallest N whose RESIDENT tridiag/penta constant working set exceeds
# the 12 MiB budget even at block_m=128 (and a multiple of the streamed
# chunk candidates, so the parity runs exercise >= 6 chunks)
BIG_N = 12288


def _tridiag_coeffs(rng, n):
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    return a, b, c


def _penta_coeffs(rng, n):
    a = rng.uniform(-1, 1, n).astype(np.float32)
    b = rng.uniform(-1, 1, n).astype(np.float32)
    d = rng.uniform(-1, 1, n).astype(np.float32)
    e = rng.uniform(-1, 1, n).astype(np.float32)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(np.float32)
    return a, b, c, d, e


def _uniform_penta_coeffs(n, s=0.11):
    one = np.ones(n, np.float32)
    return s * one, -4 * s * one, (1 + 6 * s) * one, -4 * s * one, s * one


@contextlib.contextmanager
def _debug_nans():
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", False)


# ---------------------------------------------------------------------------
# Regression: traced eps must not be concretised (jit-breaking bug)
# ---------------------------------------------------------------------------

def test_jitted_uniform_penta_pallas_solve():
    """jax.jit(solve) on a uniform-mode penta Factorization (pallas) used to
    raise ConcretizationTypeError via float(f.eps[2])."""
    n, m = 64, 96
    system = BandedSystem.penta(*_uniform_penta_coeffs(n), mode="uniform")
    fact = factorize(system, backend="pallas")
    rng = np.random.default_rng(0)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))

    got = jax.jit(solve)(fact, rhs)        # must trace, not concretise
    want = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_uniform_penta_pallas_solve_inside_scan():
    """The lax.scan PDE-loop shape over the same path (fact closed over)."""
    n, m = 64, 32
    system = BandedSystem.penta(*_uniform_penta_coeffs(n), mode="uniform")
    fact = factorize(system, backend="pallas")
    rng = np.random.default_rng(1)
    c0 = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))

    def body(c, _):
        return solve(fact, c), None

    out, _ = jax.lax.scan(body, c0, None, length=3)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# Regression: dead padded lanes must not compute 1/0 (NaN hygiene)
# ---------------------------------------------------------------------------

def test_batch_kernel_dead_lanes_are_finite():
    """M=96 at block_m=128 leaves 32 dead lanes; the zero pad used to put a
    0 main diagonal there -> 1/0 -> inf/NaN across every padded sweep row.
    pad_lanes(identity=True) pads the main diagonal with 1 instead."""
    rng = np.random.default_rng(2)
    n, m = 16, 96
    a, b, c = (rng.uniform(-1, 1, (n, m)).astype(np.float32) * 0.1
               for _ in range(3))
    b = (np.abs(a) + np.abs(c) + 2.0).astype(np.float32)
    d = rng.normal(size=(n, m)).astype(np.float32)

    with _debug_nans():
        x = kops.thomas_batch(*map(jnp.asarray, (a, b, c, d)),
                              block_m=128, interpret=True)
    assert x.shape == (n, m) and np.isfinite(np.asarray(x)).all()

    pa, pb, pc, pd, pe = _penta_coeffs(rng, n)
    tile = lambda v: np.broadcast_to(v[:, None], (n, m)).copy()
    with _debug_nans():
        x5 = kops.penta_batch(*map(jnp.asarray, (tile(pa), tile(pb), tile(pc),
                                                 tile(pd), tile(pe), d)),
                              block_m=128, interpret=True)
    assert np.isfinite(np.asarray(x5)).all()


def test_pad_lanes_identity_flag():
    x = jnp.zeros((4, 96))
    padded, m = kcommon.pad_lanes(x, 128, identity=True)
    assert m == 96 and padded.shape == (4, 128)
    assert np.asarray(padded)[:, 96:].min() == 1.0
    padded0, _ = kcommon.pad_lanes(x, 128)
    assert np.asarray(padded0)[:, 96:].max() == 0.0


# ---------------------------------------------------------------------------
# Streamed kernels: chunked == resident, bit-for-bit at small N
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,block_n,block_m", [
    (64, 128, 16, 128),
    (100, 70, 32, 64),      # ragged N and M -> sweep + lane padding
    (33, 256, 8, 128),      # odd N
])
def test_thomas_streamed_matches_resident(n, m, block_n, block_m):
    from repro.core import thomas_factor
    rng = np.random.default_rng(n * 7 + m)
    a, b, c = _tridiag_coeffs(rng, n)
    d = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    f = thomas_factor(*map(jnp.asarray, (a, b, c)))
    res = kops.thomas_constant(f, d, block_m=block_m, interpret=True)
    got = kops.thomas_constant(f, d, block_m=block_m, block_n=block_n,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(res))


@pytest.mark.parametrize("uniform", [False, True])
@pytest.mark.parametrize("n,m,block_n", [(96, 200, 32), (50, 64, 16)])
def test_penta_streamed_matches_resident(uniform, n, m, block_n):
    from repro.core import penta_factor
    rng = np.random.default_rng(n + m)
    coeffs = (_uniform_penta_coeffs(n) if uniform
              else _penta_coeffs(rng, n))
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    f = penta_factor(*map(jnp.asarray, coeffs))
    res = kops.penta_constant(f, rhs, interpret=True, uniform=uniform)
    got = kops.penta_constant(f, rhs, block_n=block_n, interpret=True,
                              uniform=uniform)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(res))


# ---------------------------------------------------------------------------
# The tentpole acceptance: large N runs pallas (streamed) instead of
# falling back, and matches reference under jit/vmap/grad
# ---------------------------------------------------------------------------

def _big_system(bandwidth, periodic):
    if bandwidth == 3:
        return BandedSystem.tridiag(-0.4, 1.8, -0.4, n=BIG_N,
                                    periodic=periodic)
    return BandedSystem.penta(0.11, -0.44, 1.66, -0.44, 0.11, n=BIG_N,
                              periodic=periodic)


@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("bandwidth", [3, 5])
def test_streamed_large_n_parity_vs_reference(bandwidth, periodic):
    """At BIG_N the resident working set exceeds the budget at every
    block_m: supports() must now say True (streamed), auto must pick
    pallas, and the solve must match reference to <= 1e-5."""
    system = _big_system(bandwidth, periodic)
    assert solver_pallas.auto_block_m(system) is None   # resident: no fit
    ok, why = solver_pallas.supports(system)
    assert ok and "streamed" in why

    fact = factorize(system, backend="auto")
    assert fact.backend == "pallas"
    assert fact.meta.opt("block_n") is not None

    rng = np.random.default_rng(bandwidth + periodic)
    rhs = jnp.asarray(rng.normal(size=(BIG_N, 130)).astype(np.float32))
    got = jax.jit(solve)(fact, rhs)
    want = solve(factorize(system, backend="reference"), rhs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_streamed_solve_under_vmap():
    """Multi-LHS: vmap over stacked streamed factorizations."""
    n, m = 128, 64
    rng = np.random.default_rng(5)
    facts = []
    for seed in (0, 1):
        r = np.random.default_rng(seed)
        a, b, c = _tridiag_coeffs(r, n)
        facts.append(factorize(BandedSystem.tridiag(a, b, c),
                               backend="pallas", block_n=32))
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *facts)
    assert stacked.meta.opt("block_n") == 32
    rhss = jnp.asarray(rng.normal(size=(2, n, m)).astype(np.float32))
    got = jax.vmap(solve)(stacked, rhss)
    for i, f in enumerate(facts):
        want = solve(f, rhss[i])
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_streamed_grad_reuses_forward_factor():
    """grad through a streamed solve: the adjoint must run the transposed
    sweeps on the SAME stored factor (reference transpose path), matching
    the reference backend's gradient."""
    n, m = 256, 32
    rng = np.random.default_rng(6)
    a, b, c = _tridiag_coeffs(rng, n)
    system = BandedSystem.tridiag(a, b, c)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))

    fact_s = factorize(system, backend="pallas", block_n=64)
    fact_r = factorize(system, backend="reference")
    loss = lambda f, r: jnp.sum(solve(f, r) ** 2)
    g_s = jax.grad(loss, argnums=1)(fact_s, rhs)
    g_r = jax.grad(loss, argnums=1)(fact_r, rhs)
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_r),
                               rtol=1e-4, atol=1e-4)
    # diagonal cotangents flow too (the spec leaves carry the gradient)
    gd_s = jax.grad(lambda diags: loss(
        factorize(BandedSystem.tridiag(*diags), backend="reference"), rhs))(
            tuple(map(jnp.asarray, (a, b, c))))
    assert all(np.isfinite(np.asarray(g)).all() for g in gd_s)


def test_streamed_solve_is_nan_clean():
    """Sweep-axis zero padding must stay finite under jax_debug_nans (the
    padded factored rows compute (0 - 0*carry)*0, never 1/0)."""
    n, m = 100, 70          # pads N 100 -> 128 at block_n=32, M 70 -> 128
    rng = np.random.default_rng(7)
    a, b, c = _tridiag_coeffs(rng, n)
    fact = factorize(BandedSystem.tridiag(a, b, c), backend="pallas",
                     block_n=32)
    rhs = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    with _debug_nans():
        x = solve(fact, rhs)
    assert np.isfinite(np.asarray(x)).all()


# ---------------------------------------------------------------------------
# Auto-tune policy + traffic model
# ---------------------------------------------------------------------------

def test_auto_tune_prefers_resident_when_it_fits():
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=256)
    assert solver_pallas.auto_tune(system) == (1024, None)


def test_auto_tune_streams_explicit_oversize_block_m():
    """An (N, block_m) pair whose resident working set exceeds the budget
    resolves to a streamed pair instead of being rejected."""
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=8192)
    ws = kcommon.vmem_working_set(8192, 1024, 2, 3, itemsize=4)
    assert ws > kcommon.VMEM_BUDGET_BYTES
    bm, bn = solver_pallas.auto_tune(system, block_m=1024)
    assert bm == 1024 and bn is not None
    ok, why = solver_pallas.supports(system, block_m=1024)
    assert ok and "streamed" in why


def test_auto_still_falls_back_when_nothing_fits(monkeypatch):
    """A budget too small even for the smallest streamed chunk must keep
    the graceful reference fallback; batch mode now STREAMS past the wall
    (the engine spills the fused factor scratch to HBM) so only
    periodic x batch still lacks a kernel."""
    system = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=64)
    monkeypatch.setattr(kcommon, "VMEM_BUDGET_BYTES", 1024)
    assert plan(system, backend="auto").backend == "reference"
    monkeypatch.undo()

    big_batch = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=BIG_N * 2,
                                     mode="batch", batch=128)
    assert solver_pallas.auto_block_m(big_batch) is None  # resident: no fit
    ok, why = solver_pallas.supports(big_batch)
    assert ok and "streamed" in why

    periodic_batch = BandedSystem.tridiag(-0.4, 1.8, -0.4, n=64,
                                          mode="batch", batch=128,
                                          periodic=True)
    ok, why = solver_pallas.supports(periodic_batch)
    assert not ok and "periodic" in why


def test_streamed_traffic_model_is_honest():
    """Streamed = 2 passes: exactly one extra RHS-sized HBM round trip and
    a re-streamed LHS; still cheaper than the per-system baseline."""
    from repro.kernels.penta import hbm_traffic_bytes as pen_t
    from repro.kernels.thomas import hbm_traffic_bytes as tri_t
    n, m = 8192, 4096
    t = tri_t(n, m)
    assert t["constant_streamed"] == t["constant"] * 2
    assert t["constant"] < t["constant_streamed"] < t["batch"]
    p = pen_t(n, m)
    assert p["constant"] < p["constant_streamed"] < p["batch"]
    assert p["uniform_streamed"] < p["constant_streamed"]
    # itemsize derives from dtype (the hardcoded-4 regression)
    assert tri_t(n, m, dtype=jnp.float64)["constant"] == 2 * t["constant"]
    assert kops.solver_hbm_traffic_bytes(3, "constant", n, m,
                                         streamed=True) == t["constant_streamed"]
