"""The gated linear-recurrence family end to end.

Four contracts, layered the way the stack is:

  * **method parity** — ``linear_recurrence{,2}`` agree across
    scan / assoc / pallas over the full (reverse x h0 x dtype) matrix,
    against an order-agnostic numpy loop (satellite: the historical
    parity gaps — nonzero h0 on assoc, reverse on assoc, bf16 dtype
    promotion — stay closed).
  * **bit-exact streaming** — forcing the streamed kernel (block_n)
    reproduces the resident kernel bit for bit, like every sweep spec.
  * **grad** — ``jax.grad`` through the Pallas custom_vjp matches the
    scan path for both orders, including the h0 cotangent.
  * **decode consistency** — the sequence models' single-token decode
    steps, replayed over a prompt, reproduce the full-sequence apply
    that now runs on the engine's Pallas recurrence kernels.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.recurrence import (_resolve, linear_recurrence,
                                   linear_recurrence2)

N, M = 37, 19  # ragged against every lane/sweep tile


def _ref1(p, q, h0, reverse):
    p = np.asarray(p, np.float64)
    q = np.asarray(q, np.float64)
    p = np.broadcast_to(p.reshape((-1,) + (1,) * (q.ndim - 1))
                        if p.ndim == 1 else p, q.shape)
    n = q.shape[0]
    carry = (np.zeros(q.shape[1:]) if h0 is None
             else np.broadcast_to(np.asarray(h0, np.float64), q.shape[1:]))
    h = np.zeros_like(q)
    for i in (range(n - 1, -1, -1) if reverse else range(n)):
        carry = p[i] * carry + q[i]
        h[i] = carry
    return h


def _ref2(s, t, u, h0, reverse):
    s = np.asarray(s, np.float64)
    t = np.asarray(t, np.float64)
    u = np.asarray(u, np.float64)
    bshape = ((-1,) + (1,) * (u.ndim - 1))
    s = np.broadcast_to(s.reshape(bshape) if s.ndim == 1 else s, u.shape)
    t = np.broadcast_to(t.reshape(bshape) if t.ndim == 1 else t, u.shape)
    n = u.shape[0]
    if h0 is None:
        c1 = c2 = np.zeros(u.shape[1:])
    else:
        c1 = np.broadcast_to(np.asarray(h0[0], np.float64), u.shape[1:])
        c2 = np.broadcast_to(np.asarray(h0[1], np.float64), u.shape[1:])
    h = np.zeros_like(u)
    for i in (range(n - 1, -1, -1) if reverse else range(n)):
        v = s[i] * c1 + t[i] * c2 + u[i]
        h[i] = v
        c2, c1 = c1, v
    return h


def _operands(rng, order, dtype):
    scales = (0.9,) if order == 1 else (0.6, 0.3)
    gates = [rng.uniform(-sc, sc, (N, M)).astype(np.float32) for sc in scales]
    q = rng.normal(size=(N, M)).astype(np.float32)
    h0 = [rng.normal(size=M).astype(np.float32) * 0.5 for _ in range(order)]
    to = lambda a: jnp.asarray(a).astype(dtype)
    return tuple(map(to, gates)), to(q), tuple(map(to, h0))


# ---------------------------------------------------------------------------
# Method parity: scan / assoc / pallas x reverse x h0 x dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["scan", "assoc", "pallas"])
@pytest.mark.parametrize("reverse", [False, True])
@pytest.mark.parametrize("with_h0", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_order1_method_parity(method, reverse, with_h0, dtype):
    rng = np.random.default_rng(3)
    (p,), q, (h0,) = _operands(rng, 1, dtype)
    h0 = h0 if with_h0 else None
    got = linear_recurrence(p, q, h0, reverse=reverse, method=method,
                            interpret=True)
    assert got.shape == (N, M) and got.dtype == dtype
    want = _ref1(np.asarray(p, np.float64), np.asarray(q, np.float64),
                 None if h0 is None else np.asarray(h0, np.float64), reverse)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("method", ["scan", "assoc", "pallas"])
@pytest.mark.parametrize("reverse", [False, True])
@pytest.mark.parametrize("with_h0", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_order2_method_parity(method, reverse, with_h0, dtype):
    rng = np.random.default_rng(5)
    (s, t), u, h0 = _operands(rng, 2, dtype)
    h0 = h0 if with_h0 else None
    got = linear_recurrence2(s, t, u, h0, reverse=reverse, method=method,
                             interpret=True)
    assert got.shape == (N, M) and got.dtype == dtype
    want = _ref2(s, t, u,
                 None if h0 is None else [np.asarray(h, np.float64)
                                          for h in h0], reverse)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("method", ["scan", "assoc", "pallas"])
def test_mixed_dtype_promotes_not_crashes(method):
    """bf16 operand + fp32 gate: every method computes in the promoted
    dtype (the scan path used to crash on the carry dtype mismatch)."""
    rng = np.random.default_rng(9)
    p = jnp.asarray(rng.uniform(-0.9, 0.9, N).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32)).astype(
        jnp.bfloat16)
    got = linear_recurrence(p, q, method=method, interpret=True)
    assert got.dtype == jnp.float32
    want = _ref1(p, np.asarray(q, np.float64), None, False)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=2e-2, atol=2e-2)


def test_shared_1d_gate_broadcasts():
    rng = np.random.default_rng(11)
    p = jnp.asarray(rng.uniform(-0.9, 0.9, N).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(N, 3, 5)).astype(np.float32))
    got = linear_recurrence(p, q, method="pallas", interpret=True)
    want = linear_recurrence(p, q, method="scan")
    assert got.shape == (N, 3, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_auto_policy_routes_floats_to_pallas():
    assert _resolve("auto", jnp.float32) == "pallas"
    assert _resolve("auto", jnp.bfloat16) == "pallas"
    assert _resolve("auto", jnp.int32) == "scan"
    with pytest.raises(ValueError, match="unknown method"):
        _resolve("woops", jnp.float32)


def test_integer_recurrence_stays_exact_on_scan():
    p = jnp.full((4,), 2, jnp.int32)
    q = jnp.ones((4, 2), jnp.int32)
    got = linear_recurrence(p, q, method="auto")
    np.testing.assert_array_equal(np.asarray(got),
                                  [[1, 1], [3, 3], [7, 7], [15, 15]])


@pytest.mark.parametrize("order", [1, 2])
@pytest.mark.parametrize("reverse", [False, True])
def test_pallas_matches_scan_within_1e5(order, reverse):
    """The acceptance bar: fp32 Pallas vs the reference scan, <= 1e-5."""
    rng = np.random.default_rng(29)
    gates, q, h0 = _operands(rng, order, jnp.float32)
    fn = linear_recurrence if order == 1 else linear_recurrence2
    h0 = h0[0] if order == 1 else h0
    got = fn(*gates, q, h0, reverse=reverse, method="pallas", interpret=True)
    want = fn(*gates, q, h0, reverse=reverse, method="scan")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Streaming bit-exactness through the front end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", [1, 2])
@pytest.mark.parametrize("reverse", [False, True])
def test_streamed_front_end_bit_exact(order, reverse):
    rng = np.random.default_rng(13)
    gates, q, h0 = _operands(rng, order, jnp.float32)
    fn = linear_recurrence if order == 1 else linear_recurrence2
    h0 = h0[0] if order == 1 else h0
    resident = fn(*gates, q, h0, reverse=reverse, method="pallas",
                  block_m=64, block_n=None, interpret=True)
    streamed = fn(*gates, q, h0, reverse=reverse, method="pallas",
                  block_m=64, block_n=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(resident), np.asarray(streamed))


# ---------------------------------------------------------------------------
# Gradients through the Pallas custom_vjp vs the scan reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reverse", [False, True])
def test_order1_grad_matches_scan(reverse):
    rng = np.random.default_rng(17)
    (p,), q, (h0,) = _operands(rng, 1, jnp.float32)

    def loss(method):
        def f(p_, q_, h0_):
            h = linear_recurrence(p_, q_, h0_, reverse=reverse,
                                  method=method, interpret=True)
            return jnp.sum(jnp.cos(h))
        return f

    gp, gq, gh = jax.grad(loss("pallas"), argnums=(0, 1, 2))(p, q, h0)
    sp, sq, sh = jax.grad(loss("scan"), argnums=(0, 1, 2))(p, q, h0)
    for a, b in ((gp, sp), (gq, sq), (gh, sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("reverse", [False, True])
def test_order2_grad_matches_scan(reverse):
    rng = np.random.default_rng(19)
    (s, t), u, h0 = _operands(rng, 2, jnp.float32)

    def loss(method):
        def f(s_, t_, u_, h1_, h2_):
            h = linear_recurrence2(s_, t_, u_, (h1_, h2_), reverse=reverse,
                                   method=method, interpret=True)
            return jnp.sum(jnp.sin(h))
        return f

    got = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3, 4))(s, t, u, *h0)
    want = jax.grad(loss("scan"), argnums=(0, 1, 2, 3, 4))(s, t, u, *h0)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Decode-vs-apply consistency at the module level (the models run the
# engine's Pallas recurrence kernels under the auto policy)
# ---------------------------------------------------------------------------

def _sctx():
    from repro.sharding import LogicalRules, ShardingCtx
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return ShardingCtx(mesh=jax.sharding.Mesh(devs, ("data", "model")),
                       rules=LogicalRules.default())


def test_rglru_decode_replay_matches_apply():
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.models.rglru import rglru_apply, rglru_decode_step, rglru_specs

    cfg = get_smoke_config("recurrentgemma_9b")
    p = init_params(rglru_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 9
    rng = np.random.default_rng(21)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3, dt)

    out_full, (h_last, conv_tail) = rglru_apply(p, x, _sctx(), cfg)

    R, W = cfg.rnn_dim, cfg.conv_width
    h = jnp.zeros((B, R), jnp.float32)
    buf = jnp.zeros((B, W - 1, R), dt)
    outs = []
    for s in range(S):
        o, h, buf = rglru_decode_step(p, x[:, s], h, buf, cfg)
        outs.append(o)
    stepped = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(stepped, np.float32),
                               np.asarray(out_full, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(buf, np.float32),
                               np.asarray(conv_tail, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_ssm_decode_replay_matches_apply():
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.models.ssm import ssm_apply, ssm_decode_step, ssm_specs

    cfg = get_smoke_config("mamba2_130m")
    p = init_params(ssm_specs(cfg), jax.random.PRNGKey(1))
    B = 2
    S = cfg.ssm_chunk * 3  # spans several inter-chunk carries
    rng = np.random.default_rng(23)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3, dt)

    out_full, state_full, _tails = ssm_apply(p, x, _sctx(), cfg)

    H, P, Nst, W = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                    cfg.conv_width)
    state = jnp.zeros((B, H, P, Nst), jnp.float32)
    bufs = {"x": jnp.zeros((B, W - 1, cfg.d_inner), dt),
            "B": jnp.zeros((B, W - 1, Nst), dt),
            "C": jnp.zeros((B, W - 1, Nst), dt)}
    outs = []
    for s in range(S):
        o, state, bufs = ssm_decode_step(p, x[:, s], state, bufs, cfg)
        outs.append(o)
    stepped = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(stepped, np.float32),
                               np.asarray(out_full, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_full),
                               rtol=3e-2, atol=3e-2)
