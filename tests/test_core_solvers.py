"""Correctness of the paper-core solvers against dense oracles."""

import random

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Fallback so the suite still runs (and keeps some property coverage)
    # in environments without hypothesis: draw a fixed pseudo-random sample
    # from each strategy instead of searching.
    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return rng.randint(self.lo, self.hi)

    class _BoolStrategy:
        def draw(self, rng):
            return rng.random() < 0.5

    class st:  # noqa: N801 - mimics hypothesis.strategies
        integers = staticmethod(_IntStrategy)
        booleans = staticmethod(_BoolStrategy)

    def settings(**_kwargs):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            # deliberately no functools.wraps: pytest would see the wrapped
            # signature and treat the strategy arguments as fixtures.
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for _ in range(10):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

from repro.core import (
    PentaOperator,
    TridiagOperator,
    dense_penta,
    dense_tridiag,
    linear_recurrence,
    linear_recurrence2,
    penta_factor,
    penta_solve,
    periodic_penta_factor,
    periodic_penta_solve,
    periodic_thomas_factor,
    periodic_thomas_solve,
    thomas_factor,
    thomas_solve,
)


def _rand_tridiag(rng, n, dominance=2.5):
    """Random diagonally-dominant tridiagonal coefficient vectors."""
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + dominance).astype(np.float32)
    return a, b, c


def _rand_penta(rng, n, dominance=4.0):
    a = rng.uniform(-1, 1, n).astype(np.float32)
    b = rng.uniform(-1, 1, n).astype(np.float32)
    d = rng.uniform(-1, 1, n).astype(np.float32)
    e = rng.uniform(-1, 1, n).astype(np.float32)
    c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + dominance).astype(np.float32)
    return a, b, c, d, e


# ---------------------------------------------------------------------------
# recurrence engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["scan", "assoc"])
@pytest.mark.parametrize("reverse", [False, True])
def test_linear_recurrence_matches_loop(method, reverse):
    rng = np.random.default_rng(0)
    n, m = 33, 5
    p = rng.uniform(-0.9, 0.9, n).astype(np.float32)
    q = rng.normal(size=(n, m)).astype(np.float32)
    h = np.zeros((n, m), np.float32)
    idx = range(n - 1, -1, -1) if reverse else range(n)
    carry = np.zeros(m, np.float32)
    for i in idx:
        carry = p[i] * carry + q[i]
        h[i] = carry
    got = linear_recurrence(jnp.asarray(p), jnp.asarray(q), reverse=reverse, method=method)
    np.testing.assert_allclose(np.asarray(got), h, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("method", ["scan", "assoc"])
@pytest.mark.parametrize("reverse", [False, True])
def test_linear_recurrence2_matches_loop(method, reverse):
    rng = np.random.default_rng(1)
    n, m = 29, 4
    s = rng.uniform(-0.6, 0.6, n).astype(np.float32)
    t = rng.uniform(-0.3, 0.3, n).astype(np.float32)
    u = rng.normal(size=(n, m)).astype(np.float32)
    h = np.zeros((n + 4, m), np.float32)  # padded
    if reverse:
        for i in range(n - 1, -1, -1):
            h[i] = s[i] * h[i + 1] + t[i] * h[i + 2] + u[i]
        want = h[:n]
    else:
        hh = np.zeros((n, m), np.float32)
        h1 = np.zeros(m, np.float32)
        h2 = np.zeros(m, np.float32)
        for i in range(n):
            hi = s[i] * h1 + t[i] * h2 + u[i]
            hh[i] = hi
            h2, h1 = h1, hi
        want = hh
    got = linear_recurrence2(jnp.asarray(s), jnp.asarray(t), jnp.asarray(u),
                             reverse=reverse, method=method)
    np.testing.assert_allclose(np.asarray(got), want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Thomas (tridiagonal)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["scan", "assoc"])
@pytest.mark.parametrize("n,m", [(4, 1), (16, 7), (128, 32), (257, 3)])
def test_thomas_constant_vs_dense(method, n, m):
    rng = np.random.default_rng(n * 1000 + m)
    a, b, c = _rand_tridiag(rng, n)
    d = rng.normal(size=(n, m)).astype(np.float32)
    f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    x = np.asarray(thomas_solve(f, jnp.asarray(d), method=method))
    A = np.asarray(dense_tridiag(a, b, c))
    want = np.linalg.solve(A.astype(np.float64), d.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=1e-4, atol=1e-4)


def test_thomas_residual_single_rhs():
    rng = np.random.default_rng(7)
    n = 64
    a, b, c = _rand_tridiag(rng, n)
    d = rng.normal(size=n).astype(np.float32)
    f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    x = np.asarray(thomas_solve(f, jnp.asarray(d)))
    A = np.asarray(dense_tridiag(a, b, c))
    np.testing.assert_allclose(A @ x, d, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [8, 65, 256])
def test_periodic_thomas_vs_dense(n):
    rng = np.random.default_rng(n)
    a, b, c = _rand_tridiag(rng, n, dominance=3.0)
    d = rng.normal(size=(n, 5)).astype(np.float32)
    pf = periodic_thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    x = np.asarray(periodic_thomas_solve(pf, jnp.asarray(d)))
    A = np.asarray(dense_tridiag(a, b, c, periodic=True))
    want = np.linalg.solve(A.astype(np.float64), d.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=2e-4, atol=2e-4)


def test_thomas_paper_constant_coefficients():
    """The paper's diffusion-equation matrix: a=c=-sigma, b=1+2sigma."""
    n = 128
    sigma = 0.37
    a = -sigma * np.ones(n, np.float32)
    b = (1 + 2 * sigma) * np.ones(n, np.float32)
    c = -sigma * np.ones(n, np.float32)
    rng = np.random.default_rng(3)
    d = rng.normal(size=(n, 16)).astype(np.float32)
    pf = periodic_thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    x = np.asarray(periodic_thomas_solve(pf, jnp.asarray(d)))
    A = np.asarray(dense_tridiag(a, b, c, periodic=True))
    want = np.linalg.solve(A.astype(np.float64), d.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Pentadiagonal
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["scan", "assoc"])
@pytest.mark.parametrize("n,m", [(6, 1), (16, 7), (128, 32), (255, 3)])
def test_penta_constant_vs_dense(method, n, m):
    rng = np.random.default_rng(n * 100 + m)
    a, b, c, d, e = _rand_penta(rng, n)
    rhs = rng.normal(size=(n, m)).astype(np.float32)
    f = penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
    x = np.asarray(penta_solve(f, jnp.asarray(rhs), method=method))
    A = np.asarray(dense_penta(a, b, c, d, e))
    want = np.linalg.solve(A.astype(np.float64), rhs.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [8, 64, 257])
def test_periodic_penta_vs_dense(n):
    rng = np.random.default_rng(n + 11)
    a, b, c, d, e = _rand_penta(rng, n, dominance=5.0)
    rhs = rng.normal(size=(n, 4)).astype(np.float32)
    pf = periodic_penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
    x = np.asarray(periodic_penta_solve(pf, jnp.asarray(rhs)))
    A = np.asarray(dense_penta(a, b, c, d, e, periodic=True))
    want = np.linalg.solve(A.astype(np.float64), rhs.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=3e-4, atol=3e-4)


def test_penta_paper_hyperdiffusion_coefficients():
    """Paper Eq. (20): a=e=sigma, b=d=-4 sigma, c=1+6 sigma (periodic)."""
    n = 256
    sigma = 0.11
    one = np.ones(n, np.float32)
    a = sigma * one; b = -4 * sigma * one; c = (1 + 6 * sigma) * one
    d = -4 * sigma * one; e = sigma * one
    rng = np.random.default_rng(5)
    rhs = rng.normal(size=(n, 8)).astype(np.float32)
    pf = periodic_penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
    x = np.asarray(periodic_penta_solve(pf, jnp.asarray(rhs)))
    A = np.asarray(dense_penta(a, b, c, d, e, periodic=True))
    want = np.linalg.solve(A.astype(np.float64), rhs.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Operator API: modes agree with each other + storage claims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("periodic", [False, True])
def test_tridiag_modes_agree(periodic):
    rng = np.random.default_rng(42)
    n, m = 96, 24
    a, b, c = _rand_tridiag(rng, n)
    d = rng.normal(size=(n, m)).astype(np.float32)
    xs = {}
    for mode in ["constant", "batch"]:
        op = TridiagOperator.create(a, b, c, mode=mode, periodic=periodic,
                                    batch=m if mode == "batch" else None)
        xs[mode] = np.asarray(op.solve(jnp.asarray(d)))
    np.testing.assert_allclose(xs["constant"], xs["batch"], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("periodic", [False, True])
def test_penta_modes_agree(periodic):
    rng = np.random.default_rng(43)
    n, m = 64, 12
    # uniform coefficients so the uniform mode is exact
    sigma = 0.21
    coef = (sigma, -4 * sigma, 1 + 6 * sigma, -4 * sigma, sigma)
    rhs = rng.normal(size=(n, m)).astype(np.float32)
    xs = {}
    for mode in ["constant", "batch", "uniform"]:
        op = PentaOperator.create(*coef, n=n, mode=mode, periodic=periodic,
                                  batch=m if mode == "batch" else None)
        xs[mode] = np.asarray(op.solve(jnp.asarray(rhs)))
    np.testing.assert_allclose(xs["constant"], xs["batch"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(xs["constant"], xs["uniform"], rtol=2e-4, atol=2e-4)


def test_storage_reduction_claims():
    """Paper: tridiag 4MN -> 3N+MN (~75 %), penta 6MN -> 5N+MN (~83 %)."""
    n, m = 1024, 4096
    tri_c = TridiagOperator.create(1.0, 4.0, 1.0, n=n, mode="constant")
    tri_b = TridiagOperator.create(1.0, 4.0, 1.0, n=n, mode="batch", batch=m)
    assert tri_c.storage_bytes()["lhs_bytes"] == 3 * n * 4
    assert tri_b.storage_bytes()["lhs_bytes"] == 3 * n * m * 4
    # LHS + RHS totals, paper's O() comparison:
    tot_c = tri_c.storage_bytes(rhs_batch=m)["total_bytes"]
    tot_b = tri_b.storage_bytes(rhs_batch=m)["total_bytes"]
    assert tot_c == (3 * n + n * m) * 4
    assert tot_b == (4 * n * m) * 4
    reduction = 1 - tot_c / tot_b
    assert reduction > 0.74  # ~75 % for M >> 1

    pen_c = PentaOperator.create(1.0, -4.0, 7.0, -4.0, 1.0, n=n, mode="constant")
    pen_b = PentaOperator.create(1.0, -4.0, 7.0, -4.0, 1.0, n=n, mode="batch", batch=m)
    pen_u = PentaOperator.create(1.0, -4.0, 7.0, -4.0, 1.0, n=n, mode="uniform")
    tot_c = pen_c.storage_bytes(rhs_batch=m)["total_bytes"]
    tot_b = pen_b.storage_bytes(rhs_batch=m)["total_bytes"]
    assert tot_c == (5 * n + n * m) * 4
    assert tot_b == (6 * n * m) * 4
    assert 1 - tot_c / tot_b > 0.82  # ~83 %
    assert pen_u.storage_bytes()["lhs_bytes"] == (4 * n + 1) * 4  # 4 vectors + scalar


# ---------------------------------------------------------------------------
# property-based: random well-conditioned systems always solve
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 200), m=st.integers(1, 8), seed=st.integers(0, 2**31 - 1),
       periodic=st.booleans())
def test_prop_tridiag_residual(n, m, seed, periodic):
    rng = np.random.default_rng(seed)
    a, b, c = _rand_tridiag(rng, n, dominance=3.0)
    d = rng.normal(size=(n, m)).astype(np.float32)
    if periodic:
        pf = periodic_thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
        x = np.asarray(periodic_thomas_solve(pf, jnp.asarray(d)))
    else:
        f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
        x = np.asarray(thomas_solve(f, jnp.asarray(d)))
    A = np.asarray(dense_tridiag(a, b, c, periodic=periodic)).astype(np.float64)
    resid = A @ x.astype(np.float64) - d
    assert np.max(np.abs(resid)) < 1e-3 * max(1.0, np.max(np.abs(d)))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 150), m=st.integers(1, 6), seed=st.integers(0, 2**31 - 1),
       periodic=st.booleans())
def test_prop_penta_residual(n, m, seed, periodic):
    rng = np.random.default_rng(seed)
    a, b, c, d, e = _rand_penta(rng, n, dominance=5.0)
    rhs = rng.normal(size=(n, m)).astype(np.float32)
    if periodic:
        pf = periodic_penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
        x = np.asarray(periodic_penta_solve(pf, jnp.asarray(rhs)))
    else:
        f = penta_factor(*map(jnp.asarray, (a, b, c, d, e)))
        x = np.asarray(penta_solve(f, jnp.asarray(rhs)))
    A = np.asarray(dense_penta(a, b, c, d, e, periodic=periodic)).astype(np.float64)
    resid = A @ x.astype(np.float64) - rhs
    assert np.max(np.abs(resid)) < 2e-3 * max(1.0, np.max(np.abs(rhs)))
