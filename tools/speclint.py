"""Developer entry point for the static verification layer.

Equivalent to ``PYTHONPATH=src python -m repro.analysis`` but runnable
from a bare checkout without setting ``PYTHONPATH`` — the same
convenience contract as ``tools/check_readme.py``.  All flags pass
through (``--self-test``, ``--nan-sweep``, ``--all``, ``-q``).

    python tools/speclint.py --all
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

if __name__ == "__main__":
    from repro.analysis.__main__ import main

    sys.exit(main())
