"""Benchmark regression gate: diff fresh bench rows against a baseline.

``benchmarks/run.py --json`` writes ``BENCH_solvers.json`` — a list of
``{name, us_per_call, backend, n, m}`` rows.  The committed copy is the
baseline; CI regenerates the rows and runs this script to compare the
two by row NAME:

  * a matched row that got more than ``--threshold`` (default 1.5x)
    slower fails the gate — on the hosted-runner noise floor a genuine
    1.5x is a broken dispatch (a kernel silently falling back to a
    reference path), not jitter;
  * rows only in the fresh file are fine (new benchmarks land freely);
  * rows only in the baseline fail — a silently DROPPED benchmark is the
    easiest way for a perf regression to hide;
  * a row carrying ``model_bytes`` (the expected HBM traffic recorded at
    bench time) is re-derived from its ``traffic`` key through the LIVE
    kernel spec registry — a mismatch fails the gate, so the roofline
    model in the repo can never drift from the numbers the perf story
    quotes.

    PYTHONPATH=src python -m benchmarks.run --json
    python tools/bench_regress.py BENCH_solvers.json --baseline <committed>

In CI the committed baseline is snapshotted (``git show HEAD:...``)
before the fresh run overwrites the file.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def load_rows(path: Path) -> dict:
    rows = json.loads(path.read_text())
    out = {}
    for row in rows:
        name, us = row.get("name"), row.get("us_per_call")
        if not isinstance(name, str) or not isinstance(us, (int, float)):
            raise SystemExit(f"error: malformed row in {path}: {row!r}")
        if name in out:
            raise SystemExit(f"error: duplicate row name {name!r} in {path}")
        out[name] = row
    return out


def expected_model_bytes(row: dict) -> int:
    """Re-derive a row's expected traffic from its recorded key, through
    the same registry resolvers the solver uses (NOT the stored number)."""
    from repro.kernels import ops as kops
    key = dict(row["traffic"])
    n, m = row["n"], row["m"]
    if "order" in key:
        return kops.recurrence_hbm_traffic_bytes(key.pop("order"), n, m,
                                                 **key)
    return kops.solver_hbm_traffic_bytes(key.pop("bandwidth"),
                                         key.pop("mode"), n, m, **key)


def check_model_bytes(fresh: dict) -> list:
    """DRIFT failures: recorded model_bytes vs the live spec derivation."""
    failures = []
    for name in sorted(fresh):
        row = fresh[name]
        if "model_bytes" not in row:
            continue
        if "traffic" not in row or row.get("n") is None:
            failures.append(f"DRIFT    {name}: model_bytes without a "
                            f"traffic key — the row cannot be re-derived")
            continue
        try:
            want = expected_model_bytes(row)
        except Exception as exc:  # registry rejected the key
            failures.append(f"DRIFT    {name}: traffic key no longer "
                            f"resolves ({type(exc).__name__}: {exc})")
            continue
        if row["model_bytes"] != want:
            failures.append(f"DRIFT    {name}: recorded model_bytes "
                            f"{row['model_bytes']} but the live spec "
                            f"derivation says {want}")
    return failures


def compare(fresh: dict, baseline: dict, threshold: float) -> list:
    """Human-readable failure lines (empty = gate passes)."""
    failures = []
    for name in sorted(baseline):
        if name not in fresh:
            failures.append(f"DROPPED  {name}: in baseline but not in the "
                            f"fresh run — benchmarks may only be removed "
                            f"with the baseline")
            continue
        was = float(baseline[name]["us_per_call"])
        now = float(fresh[name]["us_per_call"])
        if was > 0 and now / was > threshold:
            failures.append(f"SLOWER   {name}: {was:.1f} -> {now:.1f} us "
                            f"({now / was:.2f}x > {threshold:.2f}x)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", type=Path, help="freshly generated rows")
    ap.add_argument("--baseline", type=Path, required=True,
                    help="committed baseline rows")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed slowdown factor per matched row")
    args = ap.parse_args()

    fresh = load_rows(args.fresh)
    baseline = load_rows(args.baseline)
    failures = compare(fresh, baseline, args.threshold)
    failures += check_model_bytes(fresh)

    new = sorted(set(fresh) - set(baseline))
    matched = len(set(fresh) & set(baseline))
    modeled = sum(1 for r in fresh.values() if "model_bytes" in r)
    print(f"bench_regress: {matched} matched row(s), {len(new)} new, "
          f"{modeled} traffic-modeled, threshold {args.threshold:.2f}x")
    for name in new:
        print(f"  NEW      {name}: {fresh[name]['us_per_call']:.1f} us")
    for line in failures:
        print(f"  {line}")
    if failures:
        print(f"bench_regress: FAIL ({len(failures)} regression(s))",
              file=sys.stderr)
        return 1
    print("bench_regress: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
