"""Benchmark regression gate: diff fresh bench rows against a baseline.

``benchmarks/run.py --json`` writes ``BENCH_solvers.json`` — a list of
``{name, us_per_call, backend, n, m}`` rows.  The committed copy is the
baseline; CI regenerates the rows and runs this script to compare the
two by row NAME:

  * a matched row that got more than ``--threshold`` (default 1.5x)
    slower fails the gate — on the hosted-runner noise floor a genuine
    1.5x is a broken dispatch (a kernel silently falling back to a
    reference path), not jitter;
  * rows only in the fresh file are fine (new benchmarks land freely);
  * rows only in the baseline fail — a silently DROPPED benchmark is the
    easiest way for a perf regression to hide.

    PYTHONPATH=src python -m benchmarks.run --json
    python tools/bench_regress.py BENCH_solvers.json --baseline <committed>

In CI the committed baseline is snapshotted (``git show HEAD:...``)
before the fresh run overwrites the file.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_rows(path: Path) -> dict:
    rows = json.loads(path.read_text())
    out = {}
    for row in rows:
        name, us = row.get("name"), row.get("us_per_call")
        if not isinstance(name, str) or not isinstance(us, (int, float)):
            raise SystemExit(f"error: malformed row in {path}: {row!r}")
        if name in out:
            raise SystemExit(f"error: duplicate row name {name!r} in {path}")
        out[name] = float(us)
    return out


def compare(fresh: dict, baseline: dict, threshold: float) -> list:
    """Human-readable failure lines (empty = gate passes)."""
    failures = []
    for name in sorted(baseline):
        if name not in fresh:
            failures.append(f"DROPPED  {name}: in baseline but not in the "
                            f"fresh run — benchmarks may only be removed "
                            f"with the baseline")
            continue
        was, now = baseline[name], fresh[name]
        if was > 0 and now / was > threshold:
            failures.append(f"SLOWER   {name}: {was:.1f} -> {now:.1f} us "
                            f"({now / was:.2f}x > {threshold:.2f}x)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", type=Path, help="freshly generated rows")
    ap.add_argument("--baseline", type=Path, required=True,
                    help="committed baseline rows")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed slowdown factor per matched row")
    args = ap.parse_args()

    fresh = load_rows(args.fresh)
    baseline = load_rows(args.baseline)
    failures = compare(fresh, baseline, args.threshold)

    new = sorted(set(fresh) - set(baseline))
    matched = len(set(fresh) & set(baseline))
    print(f"bench_regress: {matched} matched row(s), {len(new)} new, "
          f"threshold {args.threshold:.2f}x")
    for name in new:
        print(f"  NEW      {name}: {fresh[name]:.1f} us")
    for line in failures:
        print(f"  {line}")
    if failures:
        print(f"bench_regress: FAIL ({len(failures)} regression(s))",
              file=sys.stderr)
        return 1
    print("bench_regress: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
