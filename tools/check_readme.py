"""Execute the README's quickstart code block(s).

The README is the repo's front door; a quickstart that no longer runs is
worse than none.  This script extracts every ```python fence from
README.md and executes them in one shared namespace, so CI fails the
build when the front door rots.

    PYTHONPATH=src python tools/check_readme.py [path/to/README.md]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.MULTILINE | re.DOTALL)


def main() -> int:
    readme = Path(sys.argv[1] if len(sys.argv) > 1 else "README.md")
    blocks = FENCE.findall(readme.read_text())
    if not blocks:
        print(f"error: no ```python blocks found in {readme}",
              file=sys.stderr)
        return 1
    ns: dict = {"__name__": "__readme__"}
    for i, block in enumerate(blocks, 1):
        print(f"-- running {readme} python block {i}/{len(blocks)} "
              f"({len(block.splitlines())} lines)")
        exec(compile(block, f"{readme}#block{i}", "exec"), ns)
    print(f"OK: {len(blocks)} block(s) executed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
