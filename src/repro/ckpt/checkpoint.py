"""Atomic, sharded, resharding checkpoints.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf.
  * Atomicity: written into ``.tmp_step_<N>`` then os.rename'd (restarts
    never see a torn checkpoint); a ``COMMITTED`` marker closes the write.
  * keep_k garbage collection.
  * Restore is *layout-free*: leaves are stored as full logical arrays with
    the tree structure in the manifest, so a checkpoint written on one mesh
    restores onto any other (elastic re-sharding = restore + device_put with
    the new shardings). At real scale the same manifest format holds
    per-shard chunks; on this container leaves are single chunks.
  * An optional async writer thread overlaps serialization with training.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(prefix + [str(k)], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(prefix + [f"__{i}"], v)
        else:
            flat[_SEP.join(prefix)] = node
    walk([], tree)
    return flat


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("__") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][2:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}
    return fix(root)


def save(directory: str, step: int, tree, *, keep_k: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (key, val) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(val))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_k)
    return final


def _gc(directory: str, keep_k: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep_k] if keep_k > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # remove torn writes
    for d in os.listdir(directory):
        if d.startswith(".tmp_step_"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(directory, d, "COMMITTED")):
            best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(directory: str, step: int | None = None, *, shardings=None):
    """Load a checkpoint; optionally device_put onto ``shardings`` (a pytree
    matching the saved tree) — this is the elastic re-shard path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bfloat16, fp8) as raw void bytes;
            # reinterpret via the logical dtype recorded in the manifest.
            arr = arr.view(np.dtype(meta["dtype"]))
        flat[key] = arr
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]


class AsyncWriter:
    """Overlap checkpoint serialization with training (single worker; at
    scale this is one writer per host writing its shard chunks)."""

    def __init__(self):
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            directory, step, tree, keep_k = item
            try:
                save(directory, step, tree, keep_k=keep_k)
            except Exception as e:      # surfaced on next submit/flush
                self._err = e

    def submit(self, directory: str, step: int, tree, *, keep_k: int = 3):
        if self._err:
            raise self._err
        # snapshot to host memory NOW so training can mutate buffers
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((directory, step, host_tree, keep_k))

    def flush(self):
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
