"""Mamba-2 (SSD — state-space duality) layer, chunked.

The inter-chunk state recurrence runs on ``repro.core.recurrence`` — the same
shared-coefficient first-order engine as the paper's Thomas sweeps (the
"machinery-shared" integration of the paper's technique; DESIGN.md §4).

Per head h with state (P, N):  h_t = exp(a_t) h_{t-1} + dt_t B_t x_t^T,
y_t = C_t . h_t + D x_t, a_t = -exp(A_log) dt_t. Group count G = 1 (B and C
shared across heads), following the mamba2-130m config.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linear_recurrence
from repro.sharding import ShardingCtx
from .config import ArchConfig
from .params import ParamSpec


def ssm_specs(cfg: ArchConfig) -> dict:
    D, di, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    w = cfg.conv_width
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "z_proj": ParamSpec((D, di), ("embed", "mlp"), dt),
        "x_proj": ParamSpec((D, di), ("embed", "mlp"), dt),
        "B_proj": ParamSpec((D, N), ("embed", "state"), dt),
        "C_proj": ParamSpec((D, N), ("embed", "state"), dt),
        "dt_proj": ParamSpec((D, H), ("embed", None), dt),
        "dt_bias": ParamSpec((H,), (None,), jnp.float32, init="zeros"),
        "A_log": ParamSpec((H,), (None,), jnp.float32, init="zeros"),
        "D_skip": ParamSpec((H,), (None,), jnp.float32, init="ones"),
        "conv_x": ParamSpec((w, di), ("conv", "mlp"), dt),
        "conv_B": ParamSpec((w, N), ("conv", "state"), dt),
        "conv_C": ParamSpec((w, N), ("conv", "state"), dt),
        "norm": ParamSpec((di,), (None,), jnp.float32, init="zeros"),
        "out_proj": ParamSpec((di, D), ("mlp", "embed"), dt,
                              scale=1.0 / np.sqrt(di)),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out


def _conv_step(buf: jax.Array, x_t: jax.Array, w: jax.Array):
    """buf: (B, W-1, C) previous inputs; x_t: (B, C). Returns (y_t, new_buf)."""
    full = jnp.concatenate([buf, x_t[:, None]], axis=1)      # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)
    return y, full[:, 1:]


def ssd_chunked(xh, dt, A_log, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H) post-softplus; Bm, Cm: (B, S, N).
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xf = xh.astype(jnp.float32)
    a = -jnp.exp(A_log)[None, None, :] * dt                  # (B, S, H) < 0
    ac = a.reshape(B, nc, Q, H)
    cum = jnp.cumsum(ac, axis=2)                             # inclusive
    Xc = xf.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, Q, N)

    # ---- intra-chunk (quadratic within Q) --------------------------------
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (B, nc, Q, Q)
    # clamp BEFORE exp: for masked pairs j > i the difference is positive and
    # exp overflows to inf, which poisons the backward pass of the where
    # (0 * inf = NaN). Valid pairs have non-positive differences.
    decay = jnp.exp(jnp.minimum(
        cum[:, :, :, None, :] - cum[:, :, None, :, :], 0.0))  # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    W = jnp.where(tri[None, None, :, :, None], decay, 0.0) \
        * (scores[..., None] * dtc[:, :, None, :, :])
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, Xc)

    # ---- chunk states + inter-chunk recurrence (the shared engine) -------
    cum_last = cum[:, :, -1:, :]                             # (B, nc, 1, H)
    wj = jnp.exp(cum_last - cum) * dtc                       # (B, nc, Q, H)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, wj, Xc)   # (B, nc, H, P, N)
    p_chunk = jnp.exp(cum_last[:, :, 0, :])                  # (B, nc, H)

    p_t = jnp.moveaxis(p_chunk, 1, 0)[..., None, None]       # (nc, B, H, 1, 1)
    q_t = jnp.moveaxis(S_c, 1, 0)                            # (nc, B, H, P, N)
    # auto policy: the engine's gated-recurrence Pallas kernels; the
    # per-chunk decay broadcasts to a full gate operand on dispatch
    # (fp32 carries — everything above is fp32 already)
    S_run = linear_recurrence(p_t, q_t, method="auto")       # inclusive prefix
    S_prev = jnp.concatenate([jnp.zeros_like(S_run[:1]), S_run[:-1]], axis=0)
    S_prev = jnp.moveaxis(S_prev, 0, 1)                      # (B, nc, H, P, N)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, S_prev) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(xh.dtype), S_run[-1]                     # state: (B, H, P, N)


def ssm_apply(p, x, sctx: ShardingCtx, cfg: ArchConfig):
    """Training/prefill. x: (B, S, D) -> (y, final_ssm_state, conv_tails)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.conv_width

    z = jnp.einsum("bsd,de->bse", x, p["z_proj"])
    xr = jnp.einsum("bsd,de->bse", x, p["x_proj"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["B_proj"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["C_proj"])
    dt_arg = jnp.einsum("bsd,dh->bsh", x, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_arg + p["dt_bias"][None, None, :])

    # conv tails for decode handoff: the last W-1 *pre-conv* inputs
    conv_tails = {
        "x": xr[:, -(W - 1):],
        "B": Bm[:, -(W - 1):],
        "C": Cm[:, -(W - 1):],
    }

    xr = jax.nn.silu(_causal_conv(xr, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))

    xh = xr.reshape(B, S, H, P)
    y, state = ssd_chunked(xh, dt, p["A_log"], Bm, Cm, cfg.ssm_chunk)
    y = y + p["D_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, H * P)
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return sctx.constrain(out, ("act_batch", "act_res_seq", None)), state, conv_tails


def ssm_decode_step(p, x_t, state, conv_bufs, cfg: ArchConfig):
    """x_t: (B, D); state: (B, H, P, N); conv_bufs dict of (B, W-1, C)."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x_t @ p["z_proj"]
    xr = x_t @ p["x_proj"]
    Bm = x_t @ p["B_proj"]
    Cm = x_t @ p["C_proj"]
    dt = jax.nn.softplus((x_t @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"][None, :])             # (B, H)

    xr, bx = _conv_step(conv_bufs["x"], xr, p["conv_x"])
    Bm, bB = _conv_step(conv_bufs["B"], Bm, p["conv_B"])
    Cm, bC = _conv_step(conv_bufs["C"], Cm, p["conv_C"])
    xr = jax.nn.silu(xr); Bm = jax.nn.silu(Bm); Cm = jax.nn.silu(Cm)

    xh = xr.reshape(-1, H, P).astype(jnp.float32)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)          # (B, H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + p["D_skip"][None, :, None] * xh
    y = y.reshape(-1, H * P).astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    from .layers import rmsnorm
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, state, {"x": bx, "B": bB, "C": bC}
