"""Architecture configuration (covers every family in the assigned pool)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_dispatch: str = "global"   # global | local (per-data-shard; §Perf)

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4

    # hybrid (RecurrentGemma): repeating block pattern + remainder
    block_pattern: tuple = ()     # e.g. ("rec", "rec", "attn")
    window: int = 0               # local-attention window (0 = full)
    rnn_width: int = 0            # RG-LRU width (0 -> d_model)

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    n_frames: int = 1536          # audio frontend stub length

    # VLM cross-attention
    cross_attn_every: int = 0     # every k-th layer attends to vision tokens
    vision_dim: int = 0
    n_img_tokens: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    opt_dtype: str = "float32"    # AdamW moment dtype (kimi-1T uses bf16)
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    remat: bool = True
    scan_layers: bool = True

    # attention flash-chunking (pure-JAX blockwise attention)
    q_chunk: int = 1024
    kv_chunk: int = 1024

    # ----- derived -----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode cell? (SSM / windowed attn)"""
        return self.family in ("ssm",) or (self.family == "hybrid" and self.window > 0)

    def params_dense_formula(self) -> int:
        """Rough 6ND-style N for MODEL_FLOPS accounting (see roofline)."""
        # computed precisely from the spec tree at dry-run time; this is a
        # sanity-check fallback only.
        return 0


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A smoke-test sized sibling of the same family (per the brief: small
    layers/width, few experts, tiny vocab)."""
    base = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=256,
        vocab=512,
        head_dim=32,
        rope_theta=10000.0,
        q_chunk=64,
        kv_chunk=64,
    )
    if cfg.n_experts:
        base.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                    expert_d_ff=128)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.block_pattern:
        base.update(n_layers=len(cfg.block_pattern) + 2, window=32, rnn_width=128)
    if cfg.enc_layers:
        base.update(enc_layers=2, dec_layers=2, n_layers=4, n_frames=24)
    if cfg.cross_attn_every:
        base.update(n_layers=5, cross_attn_every=5, vision_dim=96, n_img_tokens=16)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
