from .config import ArchConfig, reduced
from .model import Model, build_model
from .params import ParamSpec, abstract_params, init_params, tree_size

__all__ = ["ArchConfig", "Model", "ParamSpec", "abstract_params",
           "build_model", "init_params", "reduced", "tree_size"]
