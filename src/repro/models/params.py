"""Parameter specification trees: one definition -> init / eval_shape / shardings.

Model builders return nested dicts of ``ParamSpec``. From a spec tree we can
  * ``init_params``      — materialise real arrays (smoke tests, real training),
  * ``abstract_params``  — ShapeDtypeStruct stand-ins (the multi-pod dry-run
    never allocates the 1T-param configs),
  * ``ShardingCtx.tree_shardings`` — NamedShardings via the logical names.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    names: tuple                 # logical axis names, len == len(shape)
    dtype: Any = jnp.bfloat16
    init: str = "normal"         # normal | zeros | ones | scaled
    scale: float | None = None   # overrides the fan-in default

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_size(spec_tree) -> int:
    return int(sum(np.prod(s.shape) for s in  # speclint: allow-concretize
                   jax.tree_util.tree_leaves(spec_tree, is_leaf=_is_spec)))


def abstract_params(spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=_is_spec)


def init_params(spec_tree, rng: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    out = []
    for i, s in enumerate(leaves):
        key = jax.random.fold_in(rng, i)
        if s.init == "zeros":
            arr = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            arr = jnp.ones(s.shape, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            scale = s.scale if s.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            arr = (scale * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
