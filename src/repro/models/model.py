"""Model assembly: one ``Model`` facade per architecture family.

Families:
  dense  — decoder-only LM, GQA/MQA (mistral-large, minitron, granites)
  moe    — decoder-only with token-choice top-k MoE (dbrx, kimi-k2)
  ssm    — Mamba-2 SSD stack, attention-free (mamba2-130m)
  hybrid — RecurrentGemma: (RG-LRU, RG-LRU, local-attn) pattern
  encdec — encoder-decoder with cross attention (seamless-m4t, audio stub)
  vlm    — decoder LM with gated cross-attention to vision tokens every
           k-th layer (llama-3.2-vision, vision stub)

All layer stacks are ``lax.scan`` over stacked parameters (compile-time and
HLO size are O(1) in depth) with optional ``jax.checkpoint`` remat.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingCtx
from .config import ArchConfig
from .layers import (
    decode_attention,
    mlp_apply,
    mlp_apply_1tok,
    mlp_specs,
    rmsnorm,
    rope,
)
from .params import ParamSpec, abstract_params, init_params
from .rglru import rglru_apply, rglru_decode_step, rglru_specs
from .ssm import ssm_apply, ssm_decode_step, ssm_specs
from .transformer import (
    block_apply,
    block_decode,
    block_prefill_kv,
    block_specs,
)
from .layers import attention_specs, cache_write


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _is_spec(x):
    return isinstance(x, ParamSpec)


def stack_specs(tree, n: int):
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.names, s.dtype,
                            s.init, s.scale),
        tree, is_leaf=_is_spec)


def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# ===========================================================================
# parameter spec trees
# ===========================================================================

def _embed_specs(cfg: ArchConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab
    dt = _dt(cfg)
    return {
        "embed": ParamSpec((V, D), ("vocab", "embed"), dt,
                           scale=1.0 / np.sqrt(D)),
        "ln_f": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "unembed": ParamSpec((D, V), ("embed", "vocab"), dt),
    }


def _rec_layer_specs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "temporal": rglru_specs(cfg),
        "ln2": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "mlp": mlp_specs(cfg),
    }


def _ssm_layer_specs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    return {
        "ln": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "ssm": ssm_specs(cfg),
    }


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "self_attn": attention_specs(cfg),
        "ln2": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "cross_attn": attention_specs(cfg),
        "ln3": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "mlp": mlp_specs(cfg),
    }


def param_specs(cfg: ArchConfig) -> dict:
    fam = cfg.family
    specs = _embed_specs(cfg)
    if fam in ("dense", "moe"):
        specs["blocks"] = stack_specs(
            block_specs(cfg, moe=(fam == "moe")), cfg.n_layers)
    elif fam == "ssm":
        specs["blocks"] = stack_specs(_ssm_layer_specs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        pat = cfg.block_pattern
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        group = {}
        for i, kind in enumerate(pat):
            group[f"l{i}_{kind}"] = (_rec_layer_specs(cfg) if kind == "rec"
                                     else block_specs(cfg))
        specs["groups"] = stack_specs(group, n_groups)
        if rem:
            specs["tail"] = stack_specs(_rec_layer_specs(cfg), rem)
    elif fam == "encdec":
        D = cfg.d_model
        specs["frame_proj"] = ParamSpec((D, D), ("embed", None), _dt(cfg))
        specs["enc_blocks"] = stack_specs(block_specs(cfg), cfg.enc_layers)
        specs["enc_ln"] = ParamSpec((D,), (None,), jnp.float32, init="zeros")
        specs["dec_blocks"] = stack_specs(_dec_layer_specs(cfg), cfg.dec_layers)
    elif fam == "vlm":
        D = cfg.d_model
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        specs["img_proj"] = ParamSpec((cfg.vision_dim, D), (None, "embed"), _dt(cfg))
        group = {
            "selfs": stack_specs(block_specs(cfg), k - 1),
            "cross": block_specs(cfg, kind="cross"),
        }
        specs["groups"] = stack_specs(group, n_groups)
    else:
        raise ValueError(fam)
    return specs


# ===========================================================================
# cache spec trees (decode-time state)
# ===========================================================================

def cache_specs(cfg: ArchConfig, batch: int, seq: int) -> Any:
    fam = cfg.family
    KV, hd, dt = cfg.n_kv_heads, cfg.hd, _dt(cfg)
    kv_names = ("layers", "act_batch", "act_kv", "act_kv_seq", "act_head_dim")

    def kv(n_layers, s):
        return ParamSpec((n_layers, batch, KV, s, hd), kv_names, dt, init="zeros")

    if fam in ("dense", "moe"):
        return {"k": kv(cfg.n_layers, seq), "v": kv(cfg.n_layers, seq)}
    if fam == "ssm":
        L, H, P, N = cfg.n_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        W, di = cfg.conv_width, cfg.d_inner
        return {
            "state": ParamSpec((L, batch, H, P, N),
                               ("layers", "act_batch", None, None, None),
                               jnp.float32, init="zeros"),
            "conv_x": ParamSpec((L, batch, W - 1, di),
                                ("layers", "act_batch", None, "act_mlp"), dt,
                                init="zeros"),
            "conv_B": ParamSpec((L, batch, W - 1, N),
                                ("layers", "act_batch", None, None), dt,
                                init="zeros"),
            "conv_C": ParamSpec((L, batch, W - 1, N),
                                ("layers", "act_batch", None, None), dt,
                                init="zeros"),
        }
    if fam == "hybrid":
        pat = cfg.block_pattern
        G, rem = divmod(cfg.n_layers, len(pat))
        R, W, Wn = cfg.rnn_dim, cfg.conv_width, min(cfg.window, seq)

        def rec_state(n):
            return {
                "h": ParamSpec((n, batch, R), ("layers", "act_batch", "act_mlp"),
                               jnp.float32, init="zeros"),
                "conv": ParamSpec((n, batch, W - 1, R),
                                  ("layers", "act_batch", None, "act_mlp"), dt,
                                  init="zeros"),
            }
        out = {"groups": {}}
        for i, kind in enumerate(pat):
            if kind == "rec":
                out["groups"][f"l{i}_rec"] = rec_state(G)
            else:
                out["groups"][f"l{i}_attn"] = {
                    "k": kv(G, Wn), "v": kv(G, Wn)}
        if rem:
            out["tail"] = rec_state(rem)
        return out
    if fam == "encdec":
        F = cfg.n_frames
        return {
            "k": kv(cfg.dec_layers, seq), "v": kv(cfg.dec_layers, seq),
            "mem_k": kv(cfg.dec_layers, F), "mem_v": kv(cfg.dec_layers, F),
        }
    if fam == "vlm":
        k = cfg.cross_attn_every
        G = cfg.n_layers // k
        T = cfg.n_img_tokens
        inner = ("layers", "layers", "act_batch", "act_kv", "act_kv_seq",
                 "act_head_dim")
        return {
            "k": ParamSpec((G, k - 1, batch, KV, seq, hd), inner, dt, init="zeros"),
            "v": ParamSpec((G, k - 1, batch, KV, seq, hd), inner, dt, init="zeros"),
            "img_k": kv(G, T), "img_v": kv(G, T),
        }
    raise ValueError(fam)


def init_cache(cfg: ArchConfig, batch: int, seq: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, seq),
        is_leaf=_is_spec)


# ===========================================================================
# shared pieces
# ===========================================================================

def _embed_tokens(params, tokens, sctx: ShardingCtx, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return sctx.constrain(x, ("act_batch", "act_res_seq", None))


def ce_loss_chunked(x, unembed, labels, sctx: ShardingCtx, chunk: int = 512):
    """Cross-entropy without materialising (B, S, V) logits: seq-chunked,
    vocab-sharded, fp32 logsumexp."""
    B, S, D = x.shape
    nc = max(S // chunk, 1)
    c = S // nc
    xs = jnp.moveaxis(x.reshape(B, nc, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

    def one(args):
        xi, li = args
        logits = jnp.einsum("bsd,dv->bsv", xi, unembed).astype(jnp.float32)
        logits = sctx.constrain(logits, ("act_batch", "act_seq", "act_vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = (li >= 0).astype(jnp.float32)
        gold = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    sums, counts = jax.lax.map(one, (xs, ls))
    return sums.sum() / jnp.maximum(counts.sum(), 1.0)


def _logits_1tok(params, x, sctx: ShardingCtx, cfg: ArchConfig):
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x @ params["unembed"]).astype(jnp.float32)
    return sctx.constrain(logits, ("act_batch", "act_vocab"))


# ===========================================================================
# forward passes (train)
# ===========================================================================

def _forward_trunk(params, tokens, sctx, cfg: ArchConfig, *, img_embed=None):
    """Token trunk -> final hidden states (B, S, D) + aux losses."""
    fam = cfg.family
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed_tokens(params, tokens, sctx, cfg)
    aux = {"lb_loss": 0.0, "router_z": 0.0}

    if fam in ("dense", "moe"):
        moe = fam == "moe"

        def body_fn(p, x):
            return block_apply(p, x, sctx, cfg, positions=positions,
                               causal=True, window=cfg.window, moe=moe)
        body_fn = _maybe_remat(body_fn, cfg)

        def body(carry, p):
            x, lb, zz = carry
            x, a = body_fn(p, x)
            if moe:
                lb = lb + a["lb_loss"]
                zz = zz + a["router_z"]
            return (x, lb, zz), None

        (x, lb, zz), _ = jax.lax.scan(body, (x, 0.0, 0.0), params["blocks"])
        aux = {"lb_loss": lb, "router_z": zz}

    elif fam == "ssm":
        def body_fn(p, x):
            h, _, _ = ssm_apply(p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps),
                                sctx, cfg)
            return x + h
        body_fn = _maybe_remat(body_fn, cfg)
        x, _ = jax.lax.scan(lambda c, p: (body_fn(p, c), None), x,
                            params["blocks"])

    elif fam == "hybrid":
        pat = cfg.block_pattern

        def rec_apply(p, x):
            h, _ = rglru_apply(p["temporal"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                               sctx, cfg)
            x = x + h
            return x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps),
                                 sctx)

        def group_fn(gp, x):
            for i, kind in enumerate(pat):
                key = f"l{i}_{kind}"
                if kind == "rec":
                    x = rec_apply(gp[key], x)
                else:
                    x, _ = block_apply(gp[key], x, sctx, cfg,
                                       positions=positions, causal=True,
                                       window=cfg.window)
            return x
        group_fn = _maybe_remat(group_fn, cfg)
        x, _ = jax.lax.scan(lambda c, gp: (group_fn(gp, c), None), x,
                            params["groups"])
        if "tail" in params:
            tail_fn = _maybe_remat(rec_apply, cfg)
            x, _ = jax.lax.scan(lambda c, p: (tail_fn(p, c), None), x,
                                params["tail"])

    elif fam == "vlm":
        img_x = jnp.einsum("btv,vd->btd", img_embed.astype(_dt(cfg)),
                           params["img_proj"])
        img_x = sctx.constrain(img_x, ("act_batch", "act_res_seq", None))

        def group_fn(gp, x):
            def inner(c, p):
                y, _ = block_apply(p, c, sctx, cfg, positions=positions,
                                   causal=True)
                return y, None
            x, _ = jax.lax.scan(inner, x, gp["selfs"])
            x, _ = block_apply(gp["cross"], x, sctx, cfg, positions=positions,
                               kv_input=img_x, kind="cross", use_rope=False)
            return x
        group_fn = _maybe_remat(group_fn, cfg)
        x, _ = jax.lax.scan(lambda c, gp: (group_fn(gp, c), None), x,
                            params["groups"])
    else:
        raise ValueError(fam)

    return rmsnorm(params["ln_f"], x, cfg.norm_eps), aux


def _encode_frames(params, frames, sctx, cfg: ArchConfig):
    """Audio-stub encoder trunk. frames: (B, F, D) precomputed embeddings."""
    F = frames.shape[1]
    positions = jnp.arange(F)
    x = jnp.einsum("bfd,de->bfe", frames.astype(_dt(cfg)), params["frame_proj"])
    x = sctx.constrain(x, ("act_batch", "act_res_seq", None))

    def body_fn(p, x):
        y, _ = block_apply(p, x, sctx, cfg, positions=positions, causal=False)
        return y
    body_fn = _maybe_remat(body_fn, cfg)
    x, _ = jax.lax.scan(lambda c, p: (body_fn(p, c), None), x,
                        params["enc_blocks"])
    return rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def _dec_layer_apply(p, x, enc_out, positions, sctx, cfg: ArchConfig):
    from .layers import attention_apply
    h = attention_apply(p["self_attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                        sctx, cfg, positions=positions, causal=True)
    x = x + h
    h = attention_apply(p["cross_attn"], rmsnorm(p["ln2"], x, cfg.norm_eps),
                        sctx, cfg, positions=positions, kv_input=enc_out,
                        use_rope=False)
    x = x + h
    return x + mlp_apply(p["mlp"], rmsnorm(p["ln3"], x, cfg.norm_eps), sctx)


def _forward_encdec(params, tokens, frames, sctx, cfg: ArchConfig):
    enc_out = _encode_frames(params, frames, sctx, cfg)
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = _embed_tokens(params, tokens, sctx, cfg)

    def body_fn(p, x):
        return _dec_layer_apply(p, x, enc_out, positions, sctx, cfg)
    body_fn = _maybe_remat(body_fn, cfg)
    x, _ = jax.lax.scan(lambda c, p: (body_fn(p, c), None), x,
                        params["dec_blocks"])
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), {"lb_loss": 0.0,
                                                      "router_z": 0.0}


# ===========================================================================
# loss
# ===========================================================================

def loss_fn(params, batch, sctx: ShardingCtx, cfg: ArchConfig):
    tokens, labels = batch["tokens"], batch["labels"]
    if cfg.family == "encdec":
        x, aux = _forward_encdec(params, tokens, batch["frames"], sctx, cfg)
    elif cfg.family == "vlm":
        x, aux = _forward_trunk(params, tokens, sctx, cfg,
                                img_embed=batch["img_embed"])
    else:
        x, aux = _forward_trunk(params, tokens, sctx, cfg)
    loss = ce_loss_chunked(x, params["unembed"], labels, sctx)
    total = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["router_z"]
    return total, {"ce": loss, **aux}


# ===========================================================================
# prefill
# ===========================================================================

def prefill_fn(params, batch, sctx: ShardingCtx, cfg: ArchConfig):
    """Process a full prompt; return (last-token logits, decode cache)."""
    fam = cfg.family
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed_tokens(params, tokens, sctx, cfg)

    if fam in ("dense", "moe"):
        moe = fam == "moe"

        def body(x, p):
            k, v = block_prefill_kv(p, x, cfg, positions)
            x, _ = block_apply(p, x, sctx, cfg, positions=positions,
                               causal=True, window=cfg.window, moe=moe)
            return x, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs}

    elif fam == "ssm":
        def body(x, p):
            h, state, tails = ssm_apply(p["ssm"],
                                        rmsnorm(p["ln"], x, cfg.norm_eps),
                                        sctx, cfg)
            return x + h, (state, tails["x"], tails["B"], tails["C"])
        x, (st, cx, cb, cc) = jax.lax.scan(body, x, params["blocks"])
        cache = {"state": st, "conv_x": cx, "conv_B": cb, "conv_C": cc}

    elif fam == "hybrid":
        pat = cfg.block_pattern
        Wn = min(cfg.window, S)
        cache = {"groups": {}}

        def rec_prefill(p, x):
            h, (h_last, tail) = rglru_apply(
                p["temporal"], rmsnorm(p["ln1"], x, cfg.norm_eps), sctx, cfg)
            x = x + h
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), sctx)
            return x, (h_last, tail)

        def ring_gather(k_full, v_full):
            # place the last Wn tokens into ring slots s = p % Wn
            s = jnp.arange(Wn)
            p_s = (S - 1) - ((S - 1 - s) % Wn)                  # absolute pos
            k = jnp.take(k_full, p_s, axis=2)                   # (B, KV, Wn, hd)
            v = jnp.take(v_full, p_s, axis=2)
            return k, v

        def group_body(x, gp):
            outs = []
            for i, kind in enumerate(pat):
                key = f"l{i}_{kind}"
                if kind == "rec":
                    x, st = rec_prefill(gp[key], x)
                    outs.append(st)
                else:
                    kf, vf = block_prefill_kv(gp[key], x, cfg, positions)
                    x, _ = block_apply(gp[key], x, sctx, cfg,
                                       positions=positions, causal=True,
                                       window=cfg.window)
                    outs.append(ring_gather(kf, vf))
            return x, tuple(outs)

        x, outs = jax.lax.scan(group_body, x, params["groups"])
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}"
            if kind == "rec":
                cache["groups"][key] = {"h": outs[i][0], "conv": outs[i][1]}
            else:
                cache["groups"][f"l{i}_attn"] = {"k": outs[i][0], "v": outs[i][1]}
        if "tail" in params:
            def tail_body(x, p):
                x, st = rec_prefill(p, x)
                return x, st
            x, st = jax.lax.scan(tail_body, x, params["tail"])
            cache["tail"] = {"h": st[0], "conv": st[1]}

    elif fam == "encdec":
        enc_out = _encode_frames(params, batch["frames"], sctx, cfg)

        def body(x, p):
            xin = rmsnorm(p["ln1"], x, cfg.norm_eps)
            from .layers import attention_prefill_kv
            k, v = attention_prefill_kv(p["self_attn"], xin, cfg, positions)
            mk = jnp.einsum("bsd,dgk->bsgk", enc_out,
                            p["cross_attn"]["wk"]).transpose(0, 2, 1, 3)
            mv = jnp.einsum("bsd,dgk->bsgk", enc_out,
                            p["cross_attn"]["wv"]).transpose(0, 2, 1, 3)
            x = _dec_layer_apply(p, x, enc_out, positions, sctx, cfg)
            return x, (k, v, mk, mv)
        x, (ks, vs, mks, mvs) = jax.lax.scan(body, x, params["dec_blocks"])
        cache = {"k": ks, "v": vs, "mem_k": mks, "mem_v": mvs}

    elif fam == "vlm":
        img_x = jnp.einsum("btv,vd->btd", batch["img_embed"].astype(_dt(cfg)),
                           params["img_proj"])
        img_x = sctx.constrain(img_x, ("act_batch", "act_res_seq", None))

        def group_body(x, gp):
            def inner(c, p):
                k, v = block_prefill_kv(p, c, cfg, positions)
                y, _ = block_apply(p, c, sctx, cfg, positions=positions,
                                   causal=True)
                return y, (k, v)
            x, (ks, vs) = jax.lax.scan(inner, x, gp["selfs"])
            ik, iv = block_prefill_kv(gp["cross"], x, cfg, positions,
                                      kv_input=img_x)
            x, _ = block_apply(gp["cross"], x, sctx, cfg, positions=positions,
                               kv_input=img_x, kind="cross", use_rope=False)
            return x, (ks, vs, ik, iv)
        x, (ks, vs, iks, ivs) = jax.lax.scan(group_body, x, params["groups"])
        cache = {"k": ks, "v": vs, "img_k": iks, "img_v": ivs}
    else:
        raise ValueError(fam)

    logits = _logits_1tok(params, x[:, -1], sctx, cfg)
    return logits, cache


# ===========================================================================
# decode (one token)
# ===========================================================================

def decode_fn(params, cache, token, pos, sctx: ShardingCtx, cfg: ArchConfig):
    """token: (B,) int32; pos: scalar int32. Returns (logits, new cache)."""
    fam = cfg.family
    x = jnp.take(params["embed"], token, axis=0)
    x = sctx.constrain(x, ("act_batch", None))

    if fam in ("dense", "moe"):
        moe = fam == "moe"

        def body(x, xs):
            p, ck, cv = xs
            x, ck, cv = block_decode(p, x, ck, cv, pos, sctx, cfg, moe=moe)
            return x, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        new_cache = {"k": ks, "v": vs}

    elif fam == "ssm":
        def body(x, xs):
            p, st, cx, cb, cc = xs
            h, st, bufs = ssm_decode_step(
                p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps), st,
                {"x": cx, "B": cb, "C": cc}, cfg)
            return x + h, (st, bufs["x"], bufs["B"], bufs["C"])
        x, (st, cx, cb, cc) = jax.lax.scan(
            body, x, (params["blocks"], cache["state"], cache["conv_x"],
                      cache["conv_B"], cache["conv_C"]))
        new_cache = {"state": st, "conv_x": cx, "conv_B": cb, "conv_C": cc}

    elif fam == "hybrid":
        pat = cfg.block_pattern
        Wn = cache["groups"][[k for k in cache["groups"] if "attn" in k][0]]["k"].shape[3] \
            if any("attn" in k for k in cache["groups"]) else cfg.window
        slot = pos % Wn
        s = jnp.arange(Wn)
        p_s = pos - ((pos - s) % Wn)
        slot_pos = jnp.where(p_s >= 0, p_s, pos + 1)

        def rec_step(p, x, h_prev, buf):
            h, h_new, buf = rglru_decode_step(
                p["temporal"], rmsnorm(p["ln1"], x, cfg.norm_eps), h_prev,
                buf, cfg)
            x = x + h
            x = x + mlp_apply_1tok(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps),
                                   sctx)
            return x, h_new, buf

        new_groups = {}
        xs_list, keys = [], []
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}" if kind == "rec" else f"l{i}_attn"
            keys.append((i, kind, key))

        def group_body(x, xs):
            gp = xs[0]
            st = xs[1]
            outs = {}
            for i, kind, key in keys:
                pkey = f"l{i}_{kind}"
                if kind == "rec":
                    x, h_new, buf = rec_step(gp[pkey], x, st[key]["h"],
                                             st[key]["conv"])
                    outs[key] = {"h": h_new, "conv": buf}
                else:
                    x, ck, cv = block_decode(gp[pkey], x, st[key]["k"],
                                             st[key]["v"], pos, sctx, cfg,
                                             slot=slot, slot_pos=slot_pos)
                    outs[key] = {"k": ck, "v": cv}
            return x, outs
        x, new_groups = jax.lax.scan(group_body, x,
                                     (params["groups"], cache["groups"]))
        new_cache = {"groups": new_groups}
        if "tail" in params:
            def tail_body(x, xs):
                p, h_prev, buf = xs
                x, h_new, buf = rec_step(p, x, h_prev, buf)
                return x, (h_new, buf)
            x, (hs, bufs) = jax.lax.scan(
                tail_body, x, (params["tail"], cache["tail"]["h"],
                               cache["tail"]["conv"]))
            new_cache["tail"] = {"h": hs, "conv": bufs}

    elif fam == "encdec":
        F = cache["mem_k"].shape[3]
        mem_pos = jnp.zeros((F,), jnp.int32)

        def body(x, xs):
            p, ck, cv, mk, mv = xs
            xin = rmsnorm(p["ln1"], x, cfg.norm_eps)
            k_new = jnp.einsum("bd,dgk->bgk", xin, p["self_attn"]["wk"])
            v_new = jnp.einsum("bd,dgk->bgk", xin, p["self_attn"]["wv"])
            k_new = rope(k_new[:, None], jnp.asarray(pos)[None],
                         cfg.rope_theta)[:, 0]
            ck = cache_write(ck, k_new, pos)
            cv = cache_write(cv, v_new, pos)
            h = decode_attention(p["self_attn"], xin, ck, cv, pos, sctx, cfg)
            x = x + h
            xin2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
            h2 = decode_attention(p["cross_attn"], xin2, mk, mv, pos, sctx,
                                  cfg, slot_pos=mem_pos, use_rope=False)
            x = x + h2
            x = x + mlp_apply_1tok(p["mlp"], rmsnorm(p["ln3"], x, cfg.norm_eps),
                                   sctx)
            return x, (ck, cv)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["mem_k"], cache["mem_v"]))
        new_cache = dict(cache, k=ks, v=vs)

    elif fam == "vlm":
        T = cache["img_k"].shape[3]
        img_pos = jnp.zeros((T,), jnp.int32)

        def group_body(x, xs):
            gp, ck, cv, ik, iv = xs

            def inner(c, ys):
                p, k1, v1 = ys
                c, k1, v1 = block_decode(p, c, k1, v1, pos, sctx, cfg)
                return c, (k1, v1)
            x, (ck, cv) = jax.lax.scan(inner, x, (gp["selfs"], ck, cv))
            x, _, _ = block_decode(gp["cross"], x, ik, iv, pos, sctx, cfg,
                                   slot_pos=img_pos, write=False,
                                   use_rope=False)
            return x, (ck, cv)
        x, (ks, vs) = jax.lax.scan(group_body, x,
                                   (params["groups"], cache["k"], cache["v"],
                                    cache["img_k"], cache["img_v"]))
        new_cache = dict(cache, k=ks, v=vs)
    else:
        raise ValueError(fam)

    return _logits_1tok(params, x, sctx, cfg), new_cache


# ===========================================================================
# facade
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def param_specs(self):
        return param_specs(self.cfg)

    def abstract_params(self):
        return abstract_params(self.param_specs())

    def init(self, rng):
        return init_params(self.param_specs(), rng)

    def cache_specs(self, batch: int, seq: int):
        return cache_specs(self.cfg, batch, seq)

    def init_cache(self, batch: int, seq: int):
        return init_cache(self.cfg, batch, seq)

    def loss(self, params, batch, sctx):
        return loss_fn(params, batch, sctx, self.cfg)

    def prefill(self, params, batch, sctx):
        return prefill_fn(params, batch, sctx, self.cfg)

    def decode(self, params, cache, token, pos, sctx):
        return decode_fn(params, cache, token, pos, sctx, self.cfg)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
