"""Core transformer layers: RMSNorm, RoPE, blockwise (flash-style) attention,
GQA/MQA/cross attention with KV caches, SwiGLU MLP.

All attention math accumulates in fp32 regardless of activation dtype. The
blockwise attention is the pure-JAX flash oracle used everywhere (the dry-run
cannot lower Pallas on CPU; see DESIGN.md §10): double lax.scan/map chunking
keeps both the HLO and the live-buffer footprint small at 32k sequence
lengths.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingCtx
from .config import ArchConfig
from .params import ParamSpec

NEG_INF = -1e30


def _pick_chunk(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (non-power-of-two seq lengths,
    e.g. the 1536-frame audio encoder)."""
    c = min(want, s)
    while s % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# norm + rope
# ---------------------------------------------------------------------------

def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, D) with pos (..., L) or scalar broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = pos.astype(jnp.float32)[..., None] * freqs        # (..., L, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., L, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise flash attention (training / prefill)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D); H % KV == 0 (GQA folding).

    Online-softmax over kv chunks, outer map over q chunks: peak live tile is
    (B, q_chunk, H, kv_chunk) fp32 — never the (Sq, Sk) score matrix.
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc

    qs = (q.astype(jnp.float32) * (1.0 / np.sqrt(D))).reshape(B, nq, qc, KV, rep, D)
    ks = k.reshape(B, nk, kc, KV, D)
    vs = v.reshape(B, nk, kc, KV, D)

    def one_q_chunk(qi):
        qblk = jax.lax.dynamic_index_in_dim(qs, qi, axis=1, keepdims=False)
        q_pos = qi * qc + jnp.arange(qc)

        def inner(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(ks, ki, axis=1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vs, ki, axis=1, keepdims=False)
            s = jnp.einsum("bqgrd,bkgd->bqgrk", qblk,
                           kblk.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            k_pos = ki * kc + jnp.arange(kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqgrk,bkgd->bqgrd", p, vblk.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qc, KV, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, KV, rep), jnp.float32)
        a0 = jnp.zeros((B, qc, KV, rep, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(one_q_chunk, jnp.arange(nq))      # (nq, B, qc, KV, rep, D)
    out = jnp.moveaxis(out, 0, 1)                       # (B, nq, qc, KV, rep, D)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (self / cross, train / prefill / decode)
# ---------------------------------------------------------------------------

def attention_specs(cfg: ArchConfig, *, kv_dim: int | None = None) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kd = kv_dim or D
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((kd, KV, hd), ("embed", "kv", "head_dim"), dt),
        "wv": ParamSpec((kd, KV, hd), ("embed", "kv", "head_dim"), dt),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed"), dt,
                        scale=1.0 / np.sqrt(H * hd)),
    }


def attention_apply(p, x, sctx: ShardingCtx, cfg: ArchConfig, *,
                    positions: jax.Array, causal: bool = True,
                    window: int = 0, kv_input: jax.Array | None = None,
                    use_rope: bool = True) -> jax.Array:
    """Training/prefill path. x: (B, S, D); kv_input for cross-attention."""
    src = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", src, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", src, p["wv"])
    if use_rope and kv_input is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = sctx.constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = sctx.constrain(k, ("act_batch", "act_seq", "act_kv", None))
    v = sctx.constrain(v, ("act_batch", "act_seq", "act_kv", None))
    o = flash_attention(q, k, v, causal=causal and kv_input is None,
                        window=window, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return sctx.constrain(out, ("act_batch", "act_res_seq", None))


def attention_prefill_kv(p, x, cfg: ArchConfig, positions) -> tuple:
    """Produce rotated K/V for the cache. Layout (B, KV, S, hd) — kv-heads
    first so the sharding fallback chain prefers head sharding when
    divisible, else sequence sharding (DESIGN.md §7)."""
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    k = rope(k, positions, cfg.rope_theta)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def decode_attention(p, x, cache_k, cache_v, pos, sctx: ShardingCtx,
                     cfg: ArchConfig, *, slot_pos: jax.Array | None = None,
                     use_rope: bool = True) -> jax.Array:
    """Single-token decode. x: (B, D); cache_{k,v}: (B, KV, S, hd);
    ``slot_pos``: (S,) absolute position of each cache slot (ring buffers);
    defaults to arange(S)."""
    B, KV, S, hd = cache_k.shape
    H = cfg.n_heads
    rep = H // KV
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    if use_rope:
        q = rope(q[:, None], jnp.asarray(pos)[None], cfg.rope_theta)[:, 0]
    qf = (q.astype(jnp.float32) * (1.0 / np.sqrt(hd))).reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrk,bgsk->bgrs", qf, cache_k.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    if slot_pos is None:
        slot_pos = jnp.arange(S)
    valid = slot_pos <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bgsk->bgrk", w, cache_v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, H, hd).astype(x.dtype)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])
    return sctx.constrain(out, ("act_batch", None))


def cache_write(cache: jax.Array, new: jax.Array, slot) -> jax.Array:
    """One-hot masked write of a single token into a (B, KV, S, hd) cache —
    SPMD-friendly on a seq-sharded cache (no gather/scatter; see DESIGN.md)."""
    S = cache.shape[2]
    onehot = (jnp.arange(S) == slot).astype(cache.dtype)       # (S,)
    return cache * (1 - onehot)[None, None, :, None] + \
        new[:, :, None, :] * onehot[None, None, :, None]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "wi": ParamSpec((D, F), ("embed", "mlp"), dt),
        "wg": ParamSpec((D, F), ("embed", "mlp"), dt),
        "wo": ParamSpec((F, D), ("mlp", "embed"), dt),
    }


def mlp_apply(p, x, sctx: ShardingCtx) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = jax.nn.silu(g) * h
    h = sctx.constrain(h, ("act_batch", "act_seq", "act_mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return sctx.constrain(out, ("act_batch", "act_res_seq", None))


def mlp_apply_1tok(p, x, sctx: ShardingCtx) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]
