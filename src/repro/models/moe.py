"""Token-choice top-k Mixture-of-Experts with expert parallelism.

Dispatch is sort-based (argsort by expert id + capacity clipping) rather than
the (T, E, C) one-hot einsum of Mesh-TF: with E = 384 (kimi-k2) and 1M-token
global batches the one-hot dispatch tensor would be petabytes. The sorted
(E, C, D) expert buffer shards over the ``model`` axis (expert parallelism);
token->expert resharding lowers to scatter/gather collectives under SPMD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingCtx
from .config import ArchConfig
from .params import ParamSpec


def moe_specs(cfg: ArchConfig) -> dict:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    specs = {
        "router": ParamSpec((D, E), ("embed", None), jnp.float32,
                            scale=1.0 / np.sqrt(D)),
        "wi": ParamSpec((E, D, F), ("experts", "embed", "expert_mlp"), dt),
        "wg": ParamSpec((E, D, F), ("experts", "embed", "expert_mlp"), dt),
        "wo": ParamSpec((E, F, D), ("experts", "expert_mlp", "embed"), dt,
                        scale=1.0 / np.sqrt(F)),
    }
    if cfg.shared_expert:
        specs["shared"] = {
            "wi": ParamSpec((D, F), ("embed", "mlp"), dt),
            "wg": ParamSpec((D, F), ("embed", "mlp"), dt),
            "wo": ParamSpec((F, D), ("mlp", "embed"), dt, scale=1.0 / np.sqrt(F)),
        }
    return specs


def _dispatch(xt, topw, topi, E: int, k: int, cap: int):
    """Sort-based dispatch: tokens -> (E, cap, D) buffer + routing state.
    All indexing is local to ``xt``'s token set (T, D)."""
    T, D = xt.shape
    eid = topi.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = order // k
    w_s = topw.reshape(-1)[order]

    counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[eid_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid_s * cap + pos_in_e, E * cap)  # E*cap = drop row

    buf = jnp.zeros((E * cap + 1, D), xt.dtype)
    buf = buf.at[slot].add(xt[tok_s] * keep[:, None].astype(xt.dtype))
    return buf[: E * cap].reshape(E, cap, D), (slot, tok_s, w_s)


def _combine(out_e, routing, T: int):
    """Weighted scatter of expert outputs back to token order."""
    slot, tok_s, w_s = routing
    D = out_e.shape[-1]
    E_cap = out_e.shape[0] * out_e.shape[1]
    out_flat = jnp.concatenate(
        [out_e.reshape(E_cap, D), jnp.zeros((1, D), out_e.dtype)], axis=0)
    gathered = out_flat[slot] * w_s[:, None].astype(out_e.dtype)  # (T*k, D)
    return jnp.zeros((T, D), out_e.dtype).at[tok_s].add(gathered)


def moe_apply(p, x: jax.Array, sctx: ShardingCtx, cfg: ArchConfig):
    """x: (B, S, D) -> (out, aux_losses).

    Two dispatch modes (cfg.moe_dispatch):
      * "global" — one sorted dispatch over all tokens; the scatter crosses
        the token(data)->expert(model) sharding boundary, which the SPMD
        partitioner resolves with heavy gathers (the measured baseline).
      * "local"  — per-data-shard dispatch (vmap over DP slices, indices stay
        shard-local, capacity is per shard) and ONE resharding boundary at
        the (E, DP*cap_l, D) expert buffer — lowers to all-to-all, the
        production EP pattern (§Perf iteration).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                                 # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    eid = topi.reshape(-1)

    def expert_ffn(hidden):
        hidden = sctx.constrain(hidden, ("act_experts", None, None))
        h = jnp.einsum("ecd,edf->ecf", hidden, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", hidden, p["wg"])
        act = jax.nn.silu(g) * h
        out_e = jnp.einsum("ecf,efd->ecd", act, p["wo"])
        return sctx.constrain(out_e, ("act_experts", None, None))

    mode = getattr(cfg, "moe_dispatch", "global")
    if mode in ("local", "local2"):
        sizes = dict(zip(sctx.mesh.axis_names, sctx.mesh.devices.shape))
        DP = sizes.get("pod", 1) * sizes.get("data", 1)
        if T % DP != 0 or T // DP < 1:
            DP = 1
        Tl = T // DP
        cap = max(int(np.ceil(  # speclint: allow-concretize
            cfg.capacity_factor * Tl * k / E)), 1)

        xs = xt.reshape(DP, Tl, D)
        ws = topw.reshape(DP, Tl, k)
        ids = topi.reshape(DP, Tl, k)
        xs = sctx.constrain(xs, ("act_batch", None, None))

        # 1) per-shard dispatch (vmapped; scatter indices stay shard-local)
        bufs, routing = jax.vmap(
            lambda xl, wl, il: _dispatch(xl, wl, il, E, k, cap))(xs, ws, ids)
        # 2) ONE resharding boundary: (DP@data, E, cap, D) -> (E@model, ., .)
        merged = jnp.moveaxis(bufs, 0, 1).reshape(E, DP * cap, D)
        if cfg.moe_dispatch == "local2":
            # 2D expert-buffer layout: experts@model AND capacity@data, so
            # the FFN einsums keep a data-parallel batch dim instead of
            # all-reducing partial sums over the data axis (§Perf iter 2).
            merged = sctx.constrain(merged, ("act_experts", "act_batch", None))
        out_e = expert_ffn(merged)                           # all-to-all here
        out_e = jnp.moveaxis(out_e.reshape(E, DP, cap, D), 1, 0)
        out_e = sctx.constrain(out_e, ("act_batch", None, None, None))
        # 3) per-shard combine
        out = jax.vmap(lambda oe, r: _combine(oe, r, Tl))(out_e, routing)
        out = out.reshape(B, S, D)
    else:
        cap = max(int(np.ceil(  # speclint: allow-concretize
            cfg.capacity_factor * T * k / E)), 1)
        hidden, routing = _dispatch(xt, topw, topi, E, k, cap)
        out = _combine(expert_ffn(hidden), routing, T)
        out = out.reshape(B, S, D)

    if cfg.shared_expert:
        sh = p["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["wg"])) \
            * jnp.einsum("bsd,df->bsf", x, sh["wi"])
        out = out + jnp.einsum("bsf,fd->bsd", hs, sh["wo"])

    # ---- aux losses (load balance + router z) ---------------------------
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eid].add(1.0) / (T * k)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, {"lb_loss": lb_loss, "router_z": z_loss}
