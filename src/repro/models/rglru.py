"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(x_t W_a),  i_t = sigmoid(x_t W_i)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

The sequence scan is ``repro.core.recurrence.linear_recurrence`` with
per-token coefficients — the same engine as the paper's solver sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linear_recurrence
from repro.sharding import ShardingCtx
from .config import ArchConfig
from .params import ParamSpec
from .ssm import _causal_conv, _conv_step

RG_C = 8.0


def rglru_specs(cfg: ArchConfig) -> dict:
    D, R, W = cfg.d_model, cfg.rnn_dim, cfg.conv_width
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_x": ParamSpec((D, R), ("embed", "mlp"), dt),
        "in_gate": ParamSpec((D, R), ("embed", "mlp"), dt),
        "conv": ParamSpec((W, R), ("conv", "mlp"), dt),
        "w_a": ParamSpec((R, R), (None, "mlp"), dt, scale=1.0 / np.sqrt(R)),
        "w_i": ParamSpec((R, R), (None, "mlp"), dt, scale=1.0 / np.sqrt(R)),
        "lam": ParamSpec((R,), (None,), jnp.float32, init="zeros"),
        "out": ParamSpec((R, D), ("mlp", "embed"), dt, scale=1.0 / np.sqrt(R)),
    }


def _gates(p, xr):
    """xr: (..., R) post-conv branch input -> (log_a, gated_input)."""
    r = jax.nn.sigmoid(jnp.einsum("...r,rq->...q", xr, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...r,rq->...q", xr, p["w_i"]).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, mult * i * xr.astype(jnp.float32)


def rglru_apply(p, x, sctx: ShardingCtx, cfg: ArchConfig):
    """x: (B, S, D) -> (out, (h_last, conv_tail))."""
    W = cfg.conv_width
    xr = jnp.einsum("bsd,dr->bsr", x, p["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["in_gate"]))
    conv_tail = xr[:, -(W - 1):]
    xr = _causal_conv(xr, p["conv"])

    a, q = _gates(p, xr)                                     # (B, S, R) fp32
    a_t = jnp.moveaxis(a, 1, 0)                              # (S, B, R)
    q_t = jnp.moveaxis(q, 1, 0)
    # auto policy: the engine's gated-recurrence Pallas kernels (fp32
    # carries — the gates were computed fp32 above, bf16 activations stay
    # bf16 outside the scan)
    h = linear_recurrence(a_t, q_t, method="auto")           # (S, B, R)
    h = jnp.moveaxis(h, 0, 1).astype(x.dtype)                # (B, S, R)

    out = jnp.einsum("bsr,rd->bsd", h * gate, p["out"])
    out = sctx.constrain(out, ("act_batch", "act_res_seq", None))
    return out, (h[:, -1].astype(jnp.float32), conv_tail)


def rglru_decode_step(p, x_t, h_prev, conv_buf, cfg: ArchConfig):
    """x_t: (B, D); h_prev: (B, R) fp32; conv_buf: (B, W-1, R)."""
    xr = x_t @ p["in_x"]
    gate = jax.nn.gelu(x_t @ p["in_gate"])
    xr, buf = _conv_step(conv_buf, xr, p["conv"])
    a, q = _gates(p, xr)
    h = a * h_prev + q                                       # (B, R) fp32
    out = (h.astype(x_t.dtype) * gate) @ p["out"]
    return out, h, buf
