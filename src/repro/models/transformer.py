"""Transformer blocks (self / cross / MoE variants) shared by every
attention-bearing family, in train, prefill and decode flavours."""

from __future__ import annotations

import jax.numpy as jnp

from repro.sharding import ShardingCtx
from .config import ArchConfig
from .layers import (
    attention_apply,
    attention_prefill_kv,
    attention_specs,
    cache_write,
    decode_attention,
    mlp_apply,
    mlp_apply_1tok,
    mlp_specs,
    rmsnorm,
    rope,
)
from .moe import moe_apply, moe_specs
from .params import ParamSpec


def _f32(shape=()):
    return ParamSpec(shape if shape else (1,), tuple([None] * max(len(shape), 1)),
                     jnp.float32, init="zeros")


def block_specs(cfg: ArchConfig, *, kind: str = "self",
                kv_dim: int | None = None, moe: bool = False) -> dict:
    D = cfg.d_model
    s = {
        "ln1": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "attn": attention_specs(cfg, kv_dim=kv_dim),
        "ln2": ParamSpec((D,), (None,), jnp.float32, init="zeros"),
        "mlp": moe_specs(cfg) if moe else mlp_specs(cfg),
    }
    if kind == "cross":
        # llama-3.2-vision style gated cross-attention
        s["gate_attn"] = _f32()
        s["gate_mlp"] = _f32()
    return s


def block_apply(p, x, sctx: ShardingCtx, cfg: ArchConfig, *,
                positions, causal=True, window=0, kv_input=None,
                kind="self", moe=False, use_rope=True):
    """Full-sequence block (train / prefill). Returns (x, aux)."""
    h = attention_apply(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), sctx, cfg,
                        positions=positions, causal=causal, window=window,
                        kv_input=kv_input, use_rope=use_rope)
    if kind == "cross":
        h = jnp.tanh(p["gate_attn"].astype(x.dtype)) * h
    x = x + h
    aux = {}
    if moe:
        m, aux = moe_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), sctx, cfg)
    else:
        m = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), sctx)
    if kind == "cross":
        m = jnp.tanh(p["gate_mlp"].astype(x.dtype)) * m
    return x + m, aux


def block_prefill_kv(p, x, cfg: ArchConfig, positions, *, kv_input=None,
                     use_rope=True):
    """K/V cache entries for this block. Self-attention caches see the normed
    block input (rotated at absolute positions); cross-attention caches see
    the raw memory (``kv_input``), no RoPE. Layout (B, KV, S, hd)."""
    if kv_input is None and use_rope:
        src = rmsnorm(p["ln1"], x, cfg.norm_eps)
        return attention_prefill_kv(p["attn"], src, cfg, positions)
    src = kv_input if kv_input is not None else rmsnorm(p["ln1"], x, cfg.norm_eps)
    k = jnp.einsum("bsd,dgk->bsgk", src, p["attn"]["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", src, p["attn"]["wv"])
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def block_decode(p, x, cache_k, cache_v, pos, sctx: ShardingCtx,
                 cfg: ArchConfig, *, slot=None, slot_pos=None, moe=False,
                 write=True, use_rope=True):
    """Single-token block. x: (B, D). Returns (x, new_k, new_v)."""
    xin = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if write:
        k_new = jnp.einsum("bd,dgk->bgk", xin, p["attn"]["wk"])
        v_new = jnp.einsum("bd,dgk->bgk", xin, p["attn"]["wv"])
        if use_rope:
            k_new = rope(k_new[:, None], jnp.asarray(pos)[None],
                         cfg.rope_theta)[:, 0]
        wslot = pos if slot is None else slot
        cache_k = cache_write(cache_k, k_new, wslot)
        cache_v = cache_write(cache_v, v_new, wslot)
    h = decode_attention(p["attn"], xin, cache_k, cache_v, pos, sctx, cfg,
                         slot_pos=slot_pos, use_rope=use_rope)
    if "gate_attn" in p:
        h = jnp.tanh(p["gate_attn"].astype(x.dtype)) * h
    x = x + h
    xin2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        m, _ = moe_apply(p["mlp"], xin2[:, None, :], sctx, cfg)
        m = m[:, 0]
    else:
        m = mlp_apply_1tok(p["mlp"], xin2, sctx)
    if "gate_mlp" in p:
        m = jnp.tanh(p["gate_mlp"].astype(x.dtype)) * m
    return x + m, cache_k, cache_v
