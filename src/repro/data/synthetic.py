"""Deterministic synthetic LM data pipeline: shard-aware, resumable.

Tokens are a cheap stateless hash of (stream seed, step, position), so
  * every host/shard can materialise exactly its slice with no I/O,
  * restarts resume bit-identically from the step counter alone (the
    checkpoint stores only ``step``),
  * elastic re-sharding is trivial (the global batch is position-addressed).

The "language" has enough structure to give a learnable signal: token t+1 is
a noisy affine function of token t modulo vocab, so a model can reduce loss
well below uniform.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def _hash_u32(x: np.ndarray, seed: int) -> np.ndarray:
    x = (x.astype(np.uint64) + np.uint64(seed)) * np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(29)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(32)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.9      # P(next = affine(prev)); rest uniform noise

    def batch_at(self, step: int, *, shard: tuple[int, int] = (0, 1)) -> dict:
        """Materialise (a shard of) the global batch for ``step``.

        shard = (index, count) slices the global batch dimension (per-host
        data loading at scale)."""
        idx, count = shard
        assert self.global_batch % count == 0
        per = self.global_batch // count
        rows = np.arange(idx * per, (idx + 1) * per, dtype=np.uint64)
        base = (np.uint64(step) << np.uint64(24)) + rows[:, None]

        # column 0: hashed start token; columns evolve affinely with noise
        h0 = _hash_u32(base, self.seed)
        toks = np.zeros((per, self.seq_len + 1), np.int64)
        toks[:, 0] = h0[:, 0] % self.vocab
        noise = _hash_u32(base * np.uint64(131) +
                          np.arange(self.seq_len + 1, dtype=np.uint64)[None, :],
                          self.seed + 1)
        use_noise = (noise % np.uint32(1000)) >= np.uint32(int(self.structure * 1000))
        for j in range(1, self.seq_len + 1):
            affine = (toks[:, j - 1] * 31 + 7) % self.vocab
            toks[:, j] = np.where(use_noise[:, j], noise[:, j] % self.vocab,
                                  affine)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def iterator(self, start_step: int = 0, *, shard=(0, 1)):
        step = start_step
        while True:
            yield step, self.batch_at(step, shard=shard)
            step += 1
