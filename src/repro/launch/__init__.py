# NOTE: deliberately empty — launch modules control XLA_FLAGS before any jax
# import; nothing here may import jax.
