"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 300 --batch 8 --seq 256 --ckpt-dir artifacts/train_run

Features wired in: auto-resume from the latest committed checkpoint, async
checkpoint writer, straggler monitor (per-host timings are simulated on this
single-host container but flow through the real code path), retry wrapper
around the step, deterministic resumable data.

On CPU the default is the real ~130M mamba2-130m config; --smoke uses the
reduced config for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="artifacts/train_run")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CI-sized)")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.ckpt import AsyncWriter, latest_step, restore
    from repro.configs import get_config, get_smoke_config
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.runtime import StragglerMonitor, with_retries
    from repro.sharding import LogicalRules, ShardingCtx
    from repro.train import AdamW, make_train_step, warmup_cosine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_local_mesh()
    sctx = ShardingCtx(mesh=mesh, rules=LogicalRules.default())
    opt = AdamW(lr=warmup_cosine(args.lr, args.warmup, args.steps),
                opt_dtype=jnp.bfloat16 if cfg.opt_dtype == "bfloat16"
                else jnp.float32)

    # ---- init or auto-resume --------------------------------------------
    start = latest_step(args.ckpt_dir)
    if start is not None:
        tree, start = restore(args.ckpt_dir)
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree_util.tree_map(jnp.asarray, params)
        opt_state = jax.tree_util.tree_map(jnp.asarray, opt_state)
        print(f"[train] resumed from step {start}")
        start += 1
    else:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        start = 0
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"[train] fresh start: {cfg.name}, {n/1e6:.1f}M params")

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=0)
    step_fn = jax.jit(make_train_step(model, sctx, opt, accum=args.accum),
                      donate_argnums=(0, 1))
    step_fn = with_retries(step_fn, max_retries=2)

    writer = AsyncWriter()
    monitor = StragglerMonitor()
    t_hist = []
    log_path = os.path.join(args.ckpt_dir, "log.jsonl")
    os.makedirs(args.ckpt_dir, exist_ok=True)

    for step in range(start, args.steps):
        t0 = time.time()
        batch = ds.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        t_hist.append(dt)
        flagged = monitor.update({0: dt})   # single-host: id 0
        if step % args.log_every == 0 or step == args.steps - 1:
            toks = args.batch * args.seq / dt
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"{dt*1e3:7.1f} ms/step {toks:9.0f} tok/s"
                  + (f" STRAGGLERS {flagged}" if flagged else ""))
            with open(log_path, "a") as f:
                json.dump({"step": step, "loss": loss, "ms": dt * 1e3}, f)
                f.write("\n")
        if step > 0 and step % args.ckpt_every == 0:
            writer.submit(args.ckpt_dir, step,
                          {"params": params, "opt": opt_state})
    writer.submit(args.ckpt_dir, args.steps - 1,
                  {"params": params, "opt": opt_state})
    writer.flush()
    print(f"[train] done; final loss {loss:.4f}; "
          f"median step {sorted(t_hist)[len(t_hist)//2]*1e3:.1f} ms")


if __name__ == "__main__":
    main()
