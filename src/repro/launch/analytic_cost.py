"""Analytic compute/memory cost model — exact for the major ops of our own
model code (every einsum in repro.models is enumerated here).

Why analytic: XLA cost_analysis counts while bodies once (scan-over-layers
-> ~1 layer reported; verified in EXPERIMENTS.md §Dry-run), so compiled FLOP
counts cannot feed the roofline directly. All formulas below are 2*M*N*K per
matmul (fwd); training multiplies by 3 (bwd ~ 2x fwd) and adds the remat
re-forward where enabled (x1 extra fwd for the scanned trunk).

Memory term (HBM bytes/device/step) counts, per device:
  * parameter traffic: every weight shard is read once per use; FSDP
    all-gathered weights are written+read once per layer visit,
  * activation traffic: rw_factor x the major activation tensors per layer,
  * decode KV/state cache read (+ write of the updated slice/one-hot pass),
  * optimizer state read+write (train),
  * logits/loss traffic.
These are steady-state lower bounds (fusion-friendly); documented per term.
"""

from __future__ import annotations

import dataclasses


BF16 = 2
F32 = 4


@dataclasses.dataclass
class Cost:
    flops: float = 0.0            # global FLOPs per step
    weight_bytes: float = 0.0     # per-device HBM bytes from weights/opt
    act_bytes: float = 0.0        # per-device HBM bytes from activations
    cache_bytes: float = 0.0      # per-device HBM bytes from decode caches

    @property
    def bytes_per_device(self) -> float:
        return self.weight_bytes + self.act_bytes + self.cache_bytes


def _layer_matmul_flops(cfg, B, S, kind: str) -> tuple[float, float]:
    """(per-attn-layer, per-mlp) fwd matmul flops for full-seq passes."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    T = B * S
    attn_proj = 2.0 * T * D * (H * hd) + 2.0 * 2.0 * T * D * (KV * hd) \
        + 2.0 * T * (H * hd) * D
    mlp = 3 * 2.0 * T * D * cfg.d_ff
    return attn_proj, mlp


def _attention_flops(cfg, B, S, n_layers, *, window=0, causal=True,
                     kv_len=None) -> float:
    H, hd = cfg.n_heads, cfg.hd
    Sk = kv_len if kv_len is not None else (min(window, S) if window else S)
    f = 4.0 * B * S * Sk * H * hd * n_layers
    if causal and kv_len is None and not window:
        f *= 0.5
    return f


def _moe_flops(cfg, B, S) -> float:
    T = B * S
    f = 3 * 2.0 * T * cfg.top_k * cfg.d_model * cfg.expert_d_ff
    f += 2.0 * T * cfg.d_model * cfg.n_experts  # router
    if cfg.shared_expert:
        f += 3 * 2.0 * T * cfg.d_model * cfg.expert_d_ff
    return f


def _ssd_flops(cfg, B, S) -> float:
    """Mamba-2 SSD per the chunked einsums in models/ssm.py (fwd)."""
    di, H, P, N, Q = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, \
        cfg.ssm_state, cfg.ssm_chunk
    T = B * S
    proj = 2.0 * T * cfg.d_model * (2 * di + 2 * N + H) \
        + 2.0 * T * di * cfg.d_model            # in/out projections
    scores = 2.0 * T * Q * N                     # C.B within chunk
    intra = 2.0 * T * Q * H * P                  # W @ X
    states = 2.0 * T * N * H * P * 2             # chunk states + Y_inter
    conv = 2.0 * T * (di + 2 * N) * cfg.conv_width
    return proj + scores + intra + states + conv


def _rglru_flops(cfg, B, S) -> float:
    R = cfg.rnn_dim
    T = B * S
    return (2.0 * T * cfg.d_model * R * 2        # in_x, in_gate
            + 2.0 * T * R * R * 2                # w_a, w_i
            + 2.0 * T * R * cfg.d_model          # out
            + 10.0 * T * R)                      # scan/gates elementwise


def _unembed_flops(cfg, tokens) -> float:
    return 2.0 * tokens * cfg.d_model * cfg.vocab


def flops_for_cell(cfg, kind: str, B: int, S: int) -> dict:
    """Global FLOPs per step, split into components."""
    fam = cfg.family
    train_mult = 3.0 if kind == "train" else 1.0
    if kind == "train" and cfg.remat:
        train_mult += 1.0        # recompute of the scanned fwd
    tokens = B if kind == "decode" else B * S
    comp: dict[str, float] = {}

    if kind == "decode":
        # parameter-linear part: 2 * N_active per token (computed by caller
        # via params; here count matmuls directly at S=1)
        B1, S1 = B, 1
    else:
        B1, S1 = B, S

    if fam in ("dense", "moe"):
        ap, mlp = _layer_matmul_flops(cfg, B1, S1, kind)
        L = cfg.n_layers
        comp["proj"] = ap * L
        comp["ffn"] = (_moe_flops(cfg, B1, S1) if fam == "moe" else mlp) * L
        kv_len = S if kind == "decode" else None
        comp["attention"] = _attention_flops(cfg, B1, S1, L, window=cfg.window,
                                             kv_len=kv_len)
    elif fam == "ssm":
        comp["ssm"] = _ssd_flops(cfg, B1, S1) * cfg.n_layers
    elif fam == "hybrid":
        pat = cfg.block_pattern
        G = cfg.n_layers // len(pat)
        n_rec = G * sum(1 for k in pat if k == "rec") + cfg.n_layers % len(pat)
        n_att = G * sum(1 for k in pat if k == "attn")
        ap, mlp = _layer_matmul_flops(cfg, B1, S1, kind)
        comp["rec"] = _rglru_flops(cfg, B1, S1) * n_rec
        comp["mlp"] = mlp * cfg.n_layers
        comp["proj"] = ap * n_att
        kv_len = min(cfg.window, S) if kind == "decode" else None
        comp["attention"] = _attention_flops(cfg, B1, S1, n_att,
                                             window=cfg.window, kv_len=kv_len)
    elif fam == "vlm":
        k = cfg.cross_attn_every
        G = cfg.n_layers // k
        ap, mlp = _layer_matmul_flops(cfg, B1, S1, kind)
        comp["proj"] = ap * cfg.n_layers
        comp["ffn"] = mlp * cfg.n_layers
        comp["img_proj"] = 2.0 * B * cfg.n_img_tokens * cfg.vision_dim * cfg.d_model
        kv_len = S if kind == "decode" else None
        comp["attention"] = _attention_flops(cfg, B1, S1, G * (k - 1),
                                             kv_len=kv_len)
        comp["cross_attention"] = _attention_flops(
            cfg, B1, S1, G, causal=False, kv_len=cfg.n_img_tokens)
    elif fam == "encdec":
        F = cfg.n_frames
        ap, mlp = _layer_matmul_flops(cfg, B1, S1, kind)
        ap_enc, mlp_enc = _layer_matmul_flops(cfg, B, F, kind)
        if kind == "decode":
            comp["enc"] = 0.0   # encoder ran at prefill; cache holds memory
        else:
            comp["enc"] = (ap_enc + mlp_enc) * cfg.enc_layers \
                + _attention_flops(cfg, B, F, cfg.enc_layers, causal=False)
        comp["dec_proj"] = (ap * 2 + mlp) * cfg.dec_layers  # self+cross attn
        kv_len = S if kind == "decode" else None
        comp["dec_self"] = _attention_flops(cfg, B1, S1, cfg.dec_layers,
                                            kv_len=kv_len)
        comp["dec_cross"] = _attention_flops(cfg, B1, S1, cfg.dec_layers,
                                             causal=False, kv_len=F)
    else:
        raise ValueError(fam)

    comp["unembed"] = _unembed_flops(cfg, tokens)
    total_fwd = sum(comp.values())
    total = total_fwd * train_mult
    return {"components_fwd": comp, "fwd": total_fwd, "train_mult": train_mult,
            "total": total}


def bytes_for_cell(cfg, kind: str, B: int, S: int, *, n_dev: int,
                   params_total: float, params_active: float,
                   cache_bytes_total: float, model_shards: int = 16,
                   data_shards: int | None = None) -> dict:
    """Per-device HBM bytes per step (documented steady-state model).

    Weight traffic assumes the model-axis shard of each weight stays local
    (never gathered over 'model'); gathering over the data axes shows up in
    the *collective* term (measured from HLO), and its HBM echo is the
    write+read of the per-device gathered tile — which is exactly
    params/model_shards per pass. Activation traffic counts ``rw`` passes of
    the (per-device) residual-width tensor per layer. Decode counts one full
    cache read + the one-hot masked rewrite (the baseline cache-update
    strategy; see §Perf for the iteration on this).
    """
    tokens = B if kind == "decode" else B * S
    if data_shards is None:
        data_shards = max(min(B, n_dev // model_shards), 1)
    out: dict[str, float] = {}

    gathered_tile = params_total * BF16 / model_shards
    if kind == "train":
        opt_b = 2 if cfg.opt_dtype == "bfloat16" else 4
        passes = 3.0 + (1.0 if cfg.remat else 0.0)   # fwd, (re-fwd), bwd x2
        out["weights"] = passes * 2.0 * gathered_tile
        out["grads_opt"] = (params_total / n_dev) * (2 * BF16 + 4 * opt_b + F32)
    else:
        out["weights"] = 2.0 * params_active * BF16 / model_shards

    act_elems = (tokens / data_shards) * cfg.d_model
    depth = cfg.n_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
    rw = 8.0 if kind == "train" else 4.0
    out["activations"] = act_elems * depth * rw * BF16

    if kind == "decode":
        out["cache"] = cache_bytes_total / n_dev * 1.5   # read + one-hot write
    else:
        vocab_tile = cfg.vocab / model_shards
        passes = 2.0 if kind == "train" else 0.05        # loss rw vs last-tok
        out["logits"] = (tokens / data_shards) * vocab_tile * F32 * passes

    total = sum(out.values())
    return {"components": out, "total": total}
