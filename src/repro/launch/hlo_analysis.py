"""Roofline-term extraction from compiled XLA artifacts.

IMPORTANT CAVEAT (measured, see EXPERIMENTS.md §Dry-run): XLA's
``cost_analysis()`` visits each ``while`` body ONCE — a scan-over-layers
model reports ~1 layer of FLOPs. We therefore:

  * parse the optimized HLO *with while-loop trip-count correction* for the
    collective-bytes term (each collective's operand bytes are multiplied by
    the product of trip counts of the loops enclosing its computation) —
    this is exact for the real scanned module;
  * compute the compute/memory terms analytically from the architecture
    (``analytic_cost.py`` — exact for the major ops of our own code), and
    report the raw HLO numbers alongside for reference.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+(?:\([^)]*\)\s*->.*)?{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:to_apply|body|condition|calls|branch_computations)=\{?%?([\w\.\-,%\s]+)\}?")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _result_bytes(rhs: str) -> int:
    """Bytes of the result type at the start of an instruction RHS."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    seg = rhs[: i + 1]
                    return sum(_shape_bytes(d, s)
                               for d, s in _SHAPE_RE.findall(seg))
        return 0
    tok = rhs.split(" ", 1)[0]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tok))


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


class HloModule:
    """Light structural parse of optimized HLO text: computations, their
    instructions, while-loop trip counts, and a call graph."""

    def __init__(self, text: str):
        self.comp_instrs: dict[str, list[tuple[str, str]]] = {}
        self.instr_bytes: dict[str, int] = {}
        cur = None
        for line in text.splitlines():
            stripped = line.rstrip()
            # computation header: "name (params...) -> type {" (or ENTRY ...)
            if stripped.endswith("{") and (" -> " in stripped
                                           or stripped.lstrip().startswith("ENTRY")):
                head = stripped.lstrip()
                if head.startswith("ENTRY"):
                    head = head[len("ENTRY"):].lstrip()
                name = head.split("(", 1)[0].strip().lstrip("%").rstrip()
                if name:
                    cur = name
                    self.comp_instrs.setdefault(cur, [])
                    continue
            if stripped.strip() == "}":
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(stripped)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            self.comp_instrs[cur].append((name, rhs))
            self.instr_bytes[name] = _result_bytes(rhs)

        # map computation -> the multiplier of how many times it runs
        self._multiplier: dict[str, float] = {}
        self._compute_multipliers()

    # -- trip counts --------------------------------------------------------
    def _cond_trip_count(self, cond_comp: str) -> float:
        """Scan conditions compare the induction var against a constant."""
        best = None
        for name, rhs in self.comp_instrs.get(cond_comp, []):
            cm = re.search(r"constant\((-?\d+)\)", rhs)
            if cm and "s32[]" in rhs or (cm and "s64[]" in rhs):
                v = int(cm.group(1))
                if v > 0:
                    best = v if best is None else max(best, v)
        return float(best) if best else 1.0

    def _compute_multipliers(self):
        entry = None
        for comp in self.comp_instrs:
            if ".clone" not in comp and entry is None:
                entry = comp
        # build call edges with per-edge multiplier
        edges: dict[str, list[tuple[str, float]]] = {c: [] for c in self.comp_instrs}
        for comp, instrs in self.comp_instrs.items():
            for name, rhs in instrs:
                if " while(" in rhs or rhs.startswith("while("):
                    bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                    cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
                    if bm and bm.group(1) in self.comp_instrs:
                        trips = self._cond_trip_count(cm.group(1)) if cm else 1.0
                        edges[comp].append((bm.group(1), trips))
                    continue
                for attr in ("to_apply", "calls"):
                    am = re.search(attr + r"=%?([\w\.\-]+)", rhs)
                    if am and am.group(1) in self.comp_instrs:
                        edges[comp].append((am.group(1), 1.0))
                cm2 = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if cm2:
                    for b in _OPERAND_NAME_RE.findall(cm2.group(1)):
                        if b in self.comp_instrs:
                            edges[comp].append((b, 1.0))

        mult: dict[str, float] = {c: 0.0 for c in self.comp_instrs}
        roots = set(self.comp_instrs) - {
            child for outs in edges.values() for child, _ in outs}
        stack = [(r, 1.0) for r in roots]
        seen_guard = 0
        while stack and seen_guard < 200000:
            seen_guard += 1
            comp, m = stack.pop()
            if comp not in mult:
                continue
            mult[comp] += m
            for child, trips in edges.get(comp, []):
                stack.append((child, m * trips))
        self._multiplier = mult

    # -- collectives --------------------------------------------------------
    def collective_bytes(self) -> dict:
        out = {k: 0.0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        dyn_counts = {k: 0.0 for k in _COLLECTIVES}
        for comp, instrs in self.comp_instrs.items():
            m = self._multiplier.get(comp, 1.0) or 1.0
            for name, rhs in instrs:
                kind = None
                for k in _COLLECTIVES:
                    if re.search(rf"\b{k}(-start)?\(", rhs):
                        kind = k
                        break
                if kind is None or f"{kind}-done" in rhs:
                    continue
                # operand bytes: look up operand instruction result sizes
                paren = rhs.find("(")
                seg = rhs[paren + 1:]
                depth = 1
                end = len(seg)
                for i, ch in enumerate(seg):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                operands = _OPERAND_NAME_RE.findall(seg[:end])
                b = sum(self.instr_bytes.get(o, 0) for o in operands)
                if b == 0:
                    # fall back to the result size (all-reduce: in == out)
                    b = self.instr_bytes.get(name, 0)
                out[kind] += b * m
                counts[kind] += 1
                dyn_counts[kind] += m
        return {"by_kind": out, "counts": counts, "dynamic_counts": dyn_counts,
                "total_bytes": sum(out.values())}


def collective_bytes(hlo_text: str) -> dict:
    return HloModule(hlo_text).collective_bytes()


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "overlap_efficiency": bound / total if total else 0.0,
    }


def model_flops(cfg, shape_kind: str, tokens: int, n_params_active: float,
                n_params_total: float, attn_flops: float) -> dict:
    """MODEL_FLOPS = k . N_active . tokens (+ attention) — the 'useful'
    fraction. k = 6 train (fwd+bwd), 2 inference."""
    k = 6.0 if shape_kind == "train" else 2.0
    mf = k * n_params_active * tokens + attn_flops
    return {"model_flops": mf, "n_params_total": n_params_total,
            "n_params_active": n_params_active, "k": k,
            "attn_flops": attn_flops}
