"""Render the §Roofline table from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.roofline_report [--dir artifacts/dryrun]
        [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(directory: str, *, mesh: str = "16x16", tag: str = ""):
    cells = []
    for p in sorted(glob.glob(os.path.join(directory, "*.json"))):
        base = os.path.basename(p)
        if tag and f"__{tag}" not in base:
            continue
        if not tag and base.count("__") > 1 + ("__pod2" in base):
            continue  # skip tagged perf-experiment artifacts in the main table
        d = json.load(open(p))
        if d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def one_sentence(d: dict) -> str:
    """What would move the dominant term down."""
    dom = d["roofline"]["dominant"]
    kind = d["kind"]
    ck = d.get("collectives", {}).get("by_kind", {})
    if dom == "collective":
        top = max(ck, key=lambda k: ck[k]) if ck else "all-reduce"
        if kind == "train":
            return (f"dominated by {top}: move Megatron activation all-reduce "
                    f"to reduce-scatter+all-gather (seq-parallel) and grad "
                    f"sync off the critical path (overlap with bwd scan)")
        return (f"dominated by {top}: reshard so per-step gathered bytes "
                f"shrink (kv/head_dim sharding, batch-major decode layout)")
    if dom == "memory":
        if kind == "decode":
            return ("cache traffic bound: shrink cache bytes/step — dus "
                    "update instead of one-hot rewrite, int8/fp8 KV, or "
                    "grow batch to amortise weight reads")
        return ("HBM bound: raise arithmetic intensity — fuse, larger "
                "per-device batch, or drop remat passes")
    return ("compute bound (good): push MFU via larger tiles/fused kernels; "
            "this cell is near its best placement")


def fmt_row(d: dict, markdown: bool) -> str:
    rl = d["roofline"]
    mf = d.get("model_flops", {})
    cols = [
        f"{d['arch']}", f"{d['shape']}",
        f"{rl['compute_s']:.3g}", f"{rl['memory_s']:.3g}",
        f"{rl['collective_s']:.3g}", rl["dominant"],
        f"{mf.get('model_flops', 0):.3g}",
        f"{d.get('useful_flop_ratio', 0):.2f}",
        f"{d.get('roofline_fraction', 0):.3f}",
    ]
    sep = " | " if markdown else ","
    return sep.join(cols)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    cells = load_cells(args.dir, mesh=args.mesh, tag=args.tag)
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "MODEL_FLOPS", "useful_ratio", "roofline_frac"]
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    ok = [d for d in cells if d.get("status") == "ok"]
    ok.sort(key=lambda d: (d["arch"], d["shape"]))
    for d in ok:
        row = fmt_row(d, args.markdown)
        print(("| " + row + " |") if args.markdown else row)
    skips = [d for d in cells if d.get("status") == "skip"]
    for d in skips:
        print(f"{'| ' if args.markdown else ''}{d['arch']} {d['shape']}: "
              f"SKIP — {d['reason']}{' |' if args.markdown else ''}")
    print()
    print("### Bottleneck sentences")
    for d in ok:
        print(f"- {d['arch']} x {d['shape']}: {one_sentence(d)}")


if __name__ == "__main__":
    main()
