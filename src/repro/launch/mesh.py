"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS first.
"""

from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data", "model"); 2 pods = 512 chips with a
    leading "pod" axis (DCN)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry-run) "
            f"or on real hardware")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_local_mesh(axes=("data", "model")):
    """Single-device mesh for CPU tests/examples."""
    import jax
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return jax.sharding.Mesh(devs, axes)
