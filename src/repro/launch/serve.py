"""Batched serving driver: prefill a batch of prompts, decode with a shared
KV budget (continuous-batching-lite: finished sequences are replaced by
pending requests at the same slot).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --requests 12 --batch 4 --prompt-len 32 --gen 24
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=0)
    args = ap.parse_args()

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.sharding import LogicalRules, ShardingCtx

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    sctx = ShardingCtx(mesh=make_local_mesh(), rules=LogicalRules.default())
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.max_len or (args.prompt_len + args.gen)

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32)
             for _ in range(args.requests)]
    B = args.batch

    prefill = jax.jit(lambda p, b: model.prefill(p, b, sctx))
    decode = jax.jit(lambda p, c, t, i: model.decode(p, c, t, i, sctx))

    def pad_cache(cache, prompt_len):
        """Grow the prompt-sized prefill cache to the serving budget."""
        def grow(x):
            if x.ndim >= 4 and x.shape[-2] == prompt_len:   # (..., S, hd)
                pad = [(0, 0)] * x.ndim
                pad[-2] = (0, max_len - prompt_len)
                return jnp.pad(x, pad)
            return x
        return jax.tree_util.tree_map(grow, cache)

    served = 0
    t0 = time.time()
    tokens_out = 0
    while queue:
        wave = [queue.pop(0) for _ in range(min(B, len(queue)))]
        while len(wave) < B:                      # pad the wave
            wave.append(wave[-1])
        prompts = jnp.asarray(np.stack(wave))
        extra = {}
        if cfg.family == "vlm":
            extra["img_embed"] = jnp.zeros(
                (B, cfg.n_img_tokens, cfg.vision_dim), jnp.bfloat16)
        if cfg.family == "encdec":
            extra["frames"] = jnp.zeros((B, cfg.n_frames, cfg.d_model),
                                        jnp.bfloat16)
        logits, cache = prefill(params, {"tokens": prompts, **extra})
        cache = pad_cache(cache, args.prompt_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        for t in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + t)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        served += len(wave)
        tokens_out += args.gen * B
        gen = np.stack([np.asarray(t) for t in out], axis=1)
        print(f"[serve] wave done: batch {B}, first seq continuation: "
              f"{gen[0][:10].tolist()}")
    dt = time.time() - t0
    print(f"[serve] served {served} requests, {tokens_out} tokens in "
          f"{dt:.1f}s ({tokens_out/dt:.1f} tok/s incl. prefill)")


if __name__ == "__main__":
    main()
