import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) -----------
import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell: build the jitted step
(train_step for train shapes, serve prefill/decode for inference shapes) with
full sharding annotations, ``.lower()`` it against ShapeDtypeStruct inputs
(no allocation — the 1T-param configs never materialise), ``.compile()`` it
for the production mesh, and dump memory/cost/collective analysis to JSON.

    python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k
    python -m repro.launch.dryrun --all            # every cell, both meshes

A compile failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system, not an environment limitation.
"""


def _parse_rules(kvs):
    out = {}
    for kv in kvs or []:
        k, v = kv.split("=", 1)
        out[k] = [tuple(v.split("+"))] if v else []
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules_overrides=None, accum: int = 1, tag: str = "",
             moe_local: bool = False, grad_constrain: bool = False,
             no_remat: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import SHAPES, get_config, input_specs, shape_applicable
    from repro.launch import analytic_cost
    from repro.launch.hlo_analysis import (
        collective_bytes, model_flops, roofline_terms)
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.params import ParamSpec, abstract_params
    from repro.sharding import LogicalRules, ShardingCtx
    from repro.train import (AdamW, batch_shardings, make_decode_step,
                             make_prefill_step, make_train_step,
                             train_step_shardings, warmup_cosine)

    import dataclasses as _dc

    cfg = get_config(arch)
    if moe_local:
        mode = moe_local if isinstance(moe_local, str) else "local"
        cfg = _dc.replace(cfg, moe_dispatch=mode)
    if no_remat:
        cfg = _dc.replace(cfg, remat=False)
    seq, batch, kind = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
           "kind": kind, "seq": seq, "batch": batch, "tag": tag,
           "variant": {"moe_local": moe_local,
                       "grad_constrain": grad_constrain,
                       "no_remat": no_remat,
                       "rules": {k: [list(c) for c in v] for k, v in
                                 (rules_overrides or {}).items()}}}

    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = LogicalRules.default()
    if rules_overrides:
        rules = rules.override(**rules_overrides)
    sctx = ShardingCtx(mesh=mesh, rules=rules)
    model = build_model(cfg)
    pspecs = model.param_specs()
    p_abs = abstract_params(pspecs)
    p_sh = sctx.tree_shardings(pspecs)
    specs = input_specs(cfg, shape_name)

    # ---- parameter accounting (for MODEL_FLOPS) -------------------------
    is_spec = lambda x: isinstance(x, ParamSpec)
    total = active = 0.0
    for path, s in jax.tree_util.tree_flatten_with_path(
            pspecs, is_leaf=is_spec)[0]:
        n = float(np.prod(s.shape))
        total += n
        keys = [getattr(k, "key", str(k)) for k in path]
        if keys == ["embed"]:
            continue  # input table: gather, not matmul
        frac = (cfg.top_k / cfg.n_experts) if "experts" in s.names else 1.0
        active += n * frac
    rec["params_total"] = total
    rec["params_active"] = active

    # ---- build + lower + compile ----------------------------------------
    if kind == "train":
        opt = AdamW(lr=warmup_cosine(3e-4, 2000, 100000),
                    opt_dtype=jnp.bfloat16 if cfg.opt_dtype == "bfloat16"
                    else jnp.float32)
        step_fn = make_train_step(model, sctx, opt, accum=accum,
                                  constrain_grads=grad_constrain)
        in_sh, out_sh = train_step_shardings(model, sctx, opt, specs["batch"])
        o_abs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            opt.state_specs(pspecs), is_leaf=is_spec)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        args = (p_abs, o_abs, specs["batch"], step_abs)
        tokens = batch * seq
    elif kind == "prefill":
        step_fn = make_prefill_step(model, sctx)
        b_sh = batch_shardings(sctx, specs["batch"])
        jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
        args = (p_abs, specs["batch"])
        tokens = batch * seq
    else:  # decode
        step_fn = make_decode_step(model, sctx)
        c_sh = sctx.tree_shardings(model.cache_specs(batch, seq))
        t_sh = sctx.sharding(("act_batch",), (batch,))
        s_sh = sctx.sharding((), ())
        jitted = jax.jit(step_fn, in_shardings=(p_sh, c_sh, t_sh, s_sh),
                         donate_argnums=(1,))
        args = (p_abs, specs["cache"], specs["token"], specs["pos"])
        tokens = batch  # one new token per sequence

    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    # ---- analyses ---------------------------------------------------------
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    rec["cost_analysis"] = {
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_acc,
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }

    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in dir(ma)
            if k.endswith("_in_bytes") and isinstance(getattr(ma, k), int)
        } if ma is not None else None
    except Exception as e:  # pragma: no cover - backend-dependent
        rec["memory_analysis"] = {"error": str(e)}

    coll = collective_bytes(compiled.as_text())
    rec["collectives"] = coll

    # ---- analytic compute/memory terms (HLO flops undercount scan bodies;
    # see hlo_analysis.py docstring + EXPERIMENTS.md §Dry-run) --------------
    af = analytic_cost.flops_for_cell(cfg, kind, batch, seq)
    cache_bytes_total = 0.0
    if kind == "decode":
        cache_bytes_total = float(sum(
            np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
            for s in jax.tree_util.tree_leaves(
                model.cache_specs(batch, seq), is_leaf=is_spec)))
    ab = analytic_cost.bytes_for_cell(
        cfg, kind, batch, seq, n_dev=n_dev, params_total=total,
        params_active=active, cache_bytes_total=cache_bytes_total)
    rec["analytic"] = {"flops_global": af["total"],
                       "flops_components_fwd": af["components_fwd"],
                       "bytes_per_device": ab["total"],
                       "bytes_components": ab["components"],
                       "cache_bytes_total": cache_bytes_total}

    # ---- roofline (analytic compute/memory + measured collectives) -------
    rl = roofline_terms(af["total"] / n_dev, ab["total"],
                        coll["total_bytes"])
    rec["roofline"] = rl
    rec["roofline_raw_hlo"] = roofline_terms(flops, bytes_acc,
                                             coll["total_bytes"])

    # useful-FLOP accounting
    attn = _attn_flops(cfg, kind, batch, seq)
    mf = model_flops(cfg, kind, tokens, active, total, attn)
    rec["model_flops"] = mf
    rec["useful_flop_ratio"] = (mf["model_flops"] / af["total"]
                                if af["total"] else 0.0)
    # roofline fraction: useful compute time over the dominant-term time
    useful_compute_s = mf["model_flops"] / n_dev / 197e12
    rec["roofline_fraction"] = (useful_compute_s / rl["bound_s"]
                                if rl["bound_s"] else 0.0)
    rec["n_devices"] = n_dev
    rec["status"] = "ok"
    return rec


def _attn_flops(cfg, kind, B, S):
    """Documented approximation of 'useful' attention/SSD FLOPs (the part of
    MODEL_FLOPS not captured by k*N*D)."""
    hd, H = cfg.hd, cfg.n_heads
    mult = 3.0 if kind == "train" else 1.0  # bwd ~ 2x fwd

    def self_attn(n_layers, s_eff, causal=True):
        if kind == "decode":
            return 4.0 * B * s_eff * H * hd * n_layers
        f = 4.0 * B * S * s_eff * H * hd * n_layers
        return f * (0.5 if causal else 1.0)

    fam = cfg.family
    if fam in ("dense", "moe"):
        return mult * self_attn(cfg.n_layers, S)
    if fam == "hybrid":
        pat = cfg.block_pattern
        n_attn = (cfg.n_layers // len(pat)) * sum(1 for k in pat if k == "attn")
        w = min(cfg.window, S)
        return mult * self_attn(n_attn, w)
    if fam == "ssm":
        # SSD intra-chunk + state flops per layer ~ 2BS(Q(N+P) + 2NP)
        Q, N, P = cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_head_dim
        per_tok = 2.0 * (Q * (N + P) + 2 * N * P) * cfg.ssm_heads * 0 + \
            2.0 * (Q * N + Q * P + 2 * N * P)
        toks = B if kind == "decode" else B * S
        return mult * per_tok * toks * cfg.d_inner / cfg.ssm_head_dim
    if fam == "vlm":
        k = cfg.cross_attn_every
        G = cfg.n_layers // k
        f = self_attn(G * (k - 1), S)
        fc = self_attn(G, cfg.n_img_tokens, causal=False)
        return mult * (f + fc)
    if fam == "encdec":
        F = cfg.n_frames
        if kind == "decode":
            self_f = 4.0 * B * S * H * hd * cfg.dec_layers
            cross_f = 4.0 * B * F * H * hd * cfg.dec_layers
            return self_f + cross_f
        enc = 4.0 * B * F * F * H * hd * cfg.enc_layers
        dec = 4.0 * B * S * S * H * hd * cfg.dec_layers * 0.5
        cross = 4.0 * B * S * F * H * hd * cfg.dec_layers
        return mult * (enc + dec + cross)
    return 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--rules", nargs="*", help="logical rule overrides k=ax1+ax2")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-local", nargs="?", const="local", default=False,
                    help="MoE dispatch mode: (no value)=local, or local2")
    ap.add_argument("--grad-constrain", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs import ARCH_IDS, SHAPES  # light import (no jax use)
        cells = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                 for mp in (False, True)]
        failures = 0
        for arch, shape, mp in cells:
            suffix = "__pod2" if mp else ""
            name = f"{arch}__{shape}{suffix}{('__' + args.tag) if args.tag else ''}.json"
            path = os.path.join(args.out, name)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.rules:
                cmd += ["--rules"] + args.rules
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            dt = time.time() - t0
            if r.returncode != 0 and not os.path.exists(path):
                failures += 1
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "error",
                               "error": r.stderr[-4000:]}, f, indent=1)
            status = json.load(open(path)).get("status")
            print(f"[{status}] {name} ({dt:.0f}s)")
        sys.exit(1 if failures else 0)

    # single cell
    try:
        rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       rules_overrides=_parse_rules(args.rules),
                       accum=args.accum, tag=args.tag,
                       moe_local=args.moe_local,
                       grad_constrain=args.grad_constrain,
                       no_remat=args.no_remat)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": traceback.format_exc()[-6000:]}
    suffix = "__pod2" if args.multi_pod else ""
    tag = f"__{args.tag}" if args.tag else ""
    from repro.configs import _norm
    name = f"{_norm(args.arch)}__{args.shape}{suffix}{tag}.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("error",)}, indent=1)[:2000])
    if rec["status"] == "error":
        print(rec["error"][-3000:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
