"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attention image layers every 5th layer
(20 of 100). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per the brief: ``input_specs`` supplies
precomputed patch/tile embeddings (B, 2048, 7680) that the backbone
projects and cross-attends to (DESIGN.md §6)."""

from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    cross_attn_every=5,
    vision_dim=7680,
    n_img_tokens=2048,
    rope_theta=500000.0,
)
