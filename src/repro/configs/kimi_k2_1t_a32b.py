"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 + shared expert; trillion-param MoE.
[arXiv:2501.kimi2; unverified]

Spec-line wins over the real model where they differ (the release uses
MLA; the assigned line says GQA kv=8 — documented in DESIGN.md §6).
Optimizer moments are bf16 so params+opt fit 512 x 16 GB (DESIGN.md §9)."""

from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    n_experts=384,
    top_k=8,
    expert_d_ff=2048,
    shared_expert=True,
    opt_dtype="bfloat16",
    rope_theta=50000.0,
)
