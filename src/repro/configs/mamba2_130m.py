"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]

Attention-free: runs the long_500k cell with O(1) recurrent state. The
SSD inter-chunk scan runs on repro.core.recurrence (machinery shared
with the paper's solver sweeps — DESIGN.md §4)."""

from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,        # unused (attention-free); kept for config uniformity
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
)
