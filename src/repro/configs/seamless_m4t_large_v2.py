"""seamless-m4t-large-v2 [audio] — enc-dec, 24+24L d_model=1024 16H
(MHA kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

The modality frontend is a STUB per the brief: input_specs supplies
precomputed audio frame embeddings (B, 1536, d_model) ~= 30 s of frames
after length adaptation (DESIGN.md §6); the backbone encoder consumes
them, the decoder cross-attends."""

from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    n_frames=1536,
    rope_theta=10000.0,
)
