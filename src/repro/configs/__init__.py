"""Architecture registry + the assigned input-shape grid.

Every entry reproduces a published config (source tags in each file). The
four shape cells per arch are the assigned grid; ``long_500k`` runs only for
sub-quadratic archs (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models import ArchConfig, build_model, reduced

ARCH_IDS = [
    "llama_3_2_vision_90b",
    "mistral_large_123b",
    "minitron_4b",
    "granite_3_8b",
    "granite_34b",
    "recurrentgemma_9b",
    "dbrx_132b",
    "kimi_k2_1t_a32b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
]

def _norm(name: str) -> str:
    """External ids use dashes/dots (llama-3.2-vision-90b); modules use
    underscores."""
    return name.replace("-", "_").replace(".", "_")

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return reduced(get_config(name))


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return False, ("full quadratic attention at 524k tokens — skipped per "
                       "brief; runs only for SSM/hybrid archs")
    return True, ""


def input_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> kwargs for train_step(batch=...)
    prefill-> kwargs for prefill_step(batch=...)
    decode -> kwargs for decode_step(cache=..., token=..., pos=...)
    """
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    tok = jax.ShapeDtypeStruct((batch, seq), i32)

    def frontends():
        extra = {}
        if cfg.family == "vlm":
            extra["img_embed"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_img_tokens, cfg.vision_dim), jnp.bfloat16)
        if cfg.family == "encdec":
            extra["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        return extra

    if kind == "train":
        return {"batch": {"tokens": tok, "labels": tok, **frontends()}}
    if kind == "prefill":
        return {"batch": {"tokens": tok, **frontends()}}
    if kind == "decode":
        model = build_model(cfg)
        cache = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            model.cache_specs(batch, seq),
            is_leaf=lambda s: hasattr(s, "names"))
        return {
            "cache": cache,
            "token": jax.ShapeDtypeStruct((batch,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape_name)
