"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000; RG-LRU + local attention, pattern
(rec, rec, attn) x12 + 2 rec, window 2048. [arXiv:2402.19427; unverified]

Sub-quadratic: runs the long_500k cell (window-bounded KV + O(1) RG-LRU
state). The RG-LRU scan runs on repro.core.recurrence — the paper's
shared-coefficient recurrence engine."""

from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    window=2048,
    rnn_width=4096,
    rope_theta=10000.0,
)
