"""``pallas`` backend: the interleaved Pallas TPU kernels from
``repro.kernels``, with VMEM-aware ``block_m`` auto-tuning.

Layout (DESIGN.md §2): the system index M rides the 128-wide lane axis
(one system per lane — the paper's one system per CUDA thread), the
unknown index N is the sequential sweep axis, and the shared LHS sits in a
single VMEM block whose index_map is constant across the grid.

``block_m`` auto-tuning: the largest lane-tile from ``_BLOCK_M_CANDIDATES``
whose working set (``vmem_working_set``) fits the VMEM budget is chosen, so
bigger batches amortise the shared-LHS block over more lanes without
tripping ``check_vmem``.  ``supports()`` reports whether a system can run
on this backend at all — ``plan(backend="auto")`` consults it and falls
back to ``reference`` instead of raising.

Periodic boundaries: the kernels solve the truncated band; the rank-1
Sherman-Morrison (tridiag) / rank-4 Woodbury (penta) corner corrections are
applied outside the kernel — a handful of O(M) dots, exactly the paper's
"2-kernel pipeline".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import penta as _penta
from repro.kernels import common as _kcommon
from repro.kernels import ops as _kops

from .registry import register_backend, register_pure_backend
from .system import BandedSystem

_BLOCK_M_CANDIDATES = (1024, 512, 256, 128)


def _vmem_counts(system: BandedSystem) -> tuple:
    """(n_rhs_blocks, n_lhs_vecs) matching the check_vmem calls in
    repro.kernels.ops for each kernel this backend dispatches to."""
    if system.bandwidth == 3:
        return (6, 0) if system.mode == "batch" else (2, 3)
    return (9, 0) if system.mode == "batch" else (2, 5)


def auto_block_m(system: BandedSystem) -> int | None:
    """Largest candidate lane tile whose working set fits the VMEM budget
    (None if even the smallest does not fit)."""
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    cap = None
    if system.batch is not None:
        # no point tiling wider than the (lane-padded) batch itself
        cap = -(-system.batch // _kcommon.LANE) * _kcommon.LANE
    for bm in _BLOCK_M_CANDIDATES:
        if cap is not None and bm > max(cap, _BLOCK_M_CANDIDATES[-1]):
            continue
        ws = _kcommon.vmem_working_set(system.n, bm, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws <= _kcommon.VMEM_BUDGET_BYTES:
            return bm
    return None


def supports(system: BandedSystem, *, block_m: int | None = None) -> tuple:
    """(ok, reason). Used by ``plan(backend="auto")`` for fallback."""
    if system.periodic and system.mode == "batch":
        return False, ("no Pallas kernel for periodic per-system-LHS solves; "
                       "use backend='reference'")
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    if block_m is not None:
        # an explicit block_m must itself fit, or auto would pick pallas
        # only to have check_vmem raise at solve time
        ws = _kcommon.vmem_working_set(system.n, block_m, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws > _kcommon.VMEM_BUDGET_BYTES:
            return False, (f"working set {ws / 2**20:.1f} MiB at block_m="
                           f"{block_m} exceeds VMEM budget "
                           f"({_kcommon.VMEM_BUDGET_BYTES / 2**20:.0f} MiB)")
        return True, f"block_m={block_m}"
    bm = auto_block_m(system)
    if bm is None:
        ws = _kcommon.vmem_working_set(system.n, _BLOCK_M_CANDIDATES[-1],
                                       n_rhs, n_lhs, itemsize=itemsize)
        return False, (f"working set {ws / 2**20:.1f} MiB at block_m="
                       f"{_BLOCK_M_CANDIDATES[-1]} exceeds VMEM budget "
                       f"({_kcommon.VMEM_BUDGET_BYTES / 2**20:.0f} MiB)")
    return True, f"block_m={bm}"


def build_stored(system: BandedSystem):
    """Factor once into the kernel-facing stored pytree.

    Same factors as the reference backend, except uniform mode is kept
    full-vector — the kernel reads a stacked LHS block."""
    from .reference import build_stored as _ref_build
    return _ref_build(system, scalarize_uniform=False)


def solve_stored(bandwidth: int, mode: str, periodic: bool, stored,
                 rhs: jax.Array, *, block_m: int, unroll: int = 1,
                 interpret: bool | None = None) -> jax.Array:
    """Pure kernel dispatch given (static meta, stored pytree, rhs)."""
    squeeze = rhs.ndim == 1
    if squeeze:
        rhs = rhs[:, None]
    # no point tiling wider than the (lane-padded) RHS itself — padding
    # up to a 1024-wide tile for a 96-wide batch wastes ~10x the sweeps
    m_pad = -(-rhs.shape[1] // _kcommon.LANE) * _kcommon.LANE
    kw = dict(block_m=min(block_m, max(m_pad, _kcommon.LANE)),
              interpret=interpret, unroll=unroll)

    if bandwidth == 3:
        if mode == "batch":
            x = _kops.thomas_batch(stored["a"], stored["b"], stored["c"],
                                   rhs, **kw)
        elif periodic:
            pf = stored
            y = _kops.thomas_constant(pf.factor, rhs, **kw)
            # rank-1 Sherman-Morrison corner correction (paper Eq. 15)
            v_dot_y = y[0] + pf.v_last * y[-1]
            x = y - (v_dot_y * pf.inv_denom_sm) * pf.z[:, None]
        else:
            x = _kops.thomas_constant(stored, rhs, **kw)
    else:
        uniform = mode == "uniform"
        if mode == "batch":
            x = _kops.penta_batch(stored["a"], stored["b"], stored["c"],
                                  stored["d"], stored["e"], rhs, **kw)
        elif periodic:
            pf = stored
            y = _kops.penta_constant(pf.factor, rhs, uniform=uniform, **kw)
            # rank-4 Woodbury corner correction (4 x M dots)
            w = pf.Minv @ _penta._vty(pf.vcoef, y)
            x = y - jnp.tensordot(pf.Z, w, axes=([1], [0]))
        else:
            x = _kops.penta_constant(stored, rhs, uniform=uniform, **kw)
    return x[:, 0] if squeeze else x


# -- the pure-function contract (repro.solver.functional) -------------------

def _pure_build(system: BandedSystem, *, block_m: int | None = None,
                unroll: int = 1, interpret: bool | None = None, **_ignored):
    ok, why = supports(system, block_m=block_m)
    if not ok:
        raise NotImplementedError(
            f"pallas backend cannot run {system.describe()}: {why}")
    resolved = block_m if block_m is not None else auto_block_m(system)
    return (build_stored(system),
            {"block_m": resolved, "unroll": unroll, "interpret": interpret})


def _pure_solve(meta, stored, rhs):
    return solve_stored(meta.bandwidth, meta.mode, meta.periodic, stored, rhs,
                        block_m=meta.opt("block_m"),
                        unroll=meta.opt("unroll", 1),
                        interpret=meta.opt("interpret"))


def _pure_transpose(meta, stored, rhs):
    # The adjoint reuses the SAME stored factor via the reference transposed
    # sweeps (A^T = U^T L^T from the forward's vectors) — transposed Pallas
    # kernels are not needed for correctness, only a future perf item.
    from .reference import transpose_solve_stored
    return transpose_solve_stored(meta.bandwidth, meta.mode, meta.periodic,
                                  meta.n, stored, rhs)


register_pure_backend("pallas", build=_pure_build, solve=_pure_solve,
                      transpose_solve=_pure_transpose)


@register_backend("pallas")
class PallasBackend:
    """Interleaved Pallas TPU kernels (``interpret=True`` off-TPU).

    Thin shim over ``factorize``/``solve``: holds a ``Factorization`` whose
    static meta froze the auto-tuned ``block_m``, and routes solves through
    the differentiable ``custom_vjp`` entry point.
    """

    def __init__(self, system: BandedSystem, *, block_m: int | None = None,
                 unroll: int = 1, interpret: bool | None = None,
                 method=None, mesh=None, batch_axis=None):
        del method, mesh, batch_axis  # option-set parity with other backends
        from .functional import factorize
        self.system = system
        self.fact = factorize(system, backend="pallas", block_m=block_m,
                              unroll=unroll, interpret=interpret)
        self.block_m = self.fact.meta.opt("block_m")
        self.unroll = unroll
        self.interpret = interpret
        self.stored = self.fact.stored

    def solve(self, rhs: jax.Array, *, unroll: int | None = None,
              method=None) -> jax.Array:
        del method  # the sweep schedule is fixed by the kernel
        from .autodiff import solve as _solve
        from .functional import with_options
        fact = self.fact
        if unroll is not None:
            fact = with_options(fact, unroll=unroll)
        return _solve(fact, rhs)
