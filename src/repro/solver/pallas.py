"""``pallas`` backend: the interleaved Pallas TPU kernels from
``repro.kernels``, with VMEM-aware ``block_m`` auto-tuning.

Layout (DESIGN.md §2): the system index M rides the 128-wide lane axis
(one system per lane — the paper's one system per CUDA thread), the
unknown index N is the sequential sweep axis, and the shared LHS sits in a
single VMEM block whose index_map is constant across the grid.

``block_m`` auto-tuning: the largest lane-tile from ``_BLOCK_M_CANDIDATES``
whose working set (``vmem_working_set``) fits the VMEM budget is chosen, so
bigger batches amortise the shared-LHS block over more lanes without
tripping ``check_vmem``.  ``supports()`` reports whether a system can run
on this backend at all — ``plan(backend="auto")`` consults it and falls
back to ``reference`` instead of raising.

Periodic boundaries: the kernels solve the truncated band; the rank-1
Sherman-Morrison (tridiag) / rank-4 Woodbury (penta) corner corrections are
applied outside the kernel — a handful of O(M) dots, exactly the paper's
"2-kernel pipeline".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import penta as _penta
from repro.core import tridiag as _tridiag
from repro.kernels import common as _kcommon
from repro.kernels import ops as _kops

from .registry import register_backend
from .system import BandedSystem

_BLOCK_M_CANDIDATES = (1024, 512, 256, 128)


def _vmem_counts(system: BandedSystem) -> tuple:
    """(n_rhs_blocks, n_lhs_vecs) matching the check_vmem calls in
    repro.kernels.ops for each kernel this backend dispatches to."""
    if system.bandwidth == 3:
        return (6, 0) if system.mode == "batch" else (2, 3)
    return (9, 0) if system.mode == "batch" else (2, 5)


def auto_block_m(system: BandedSystem) -> int | None:
    """Largest candidate lane tile whose working set fits the VMEM budget
    (None if even the smallest does not fit)."""
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    cap = None
    if system.batch is not None:
        # no point tiling wider than the (lane-padded) batch itself
        cap = -(-system.batch // _kcommon.LANE) * _kcommon.LANE
    for bm in _BLOCK_M_CANDIDATES:
        if cap is not None and bm > max(cap, _BLOCK_M_CANDIDATES[-1]):
            continue
        ws = _kcommon.vmem_working_set(system.n, bm, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws <= _kcommon.VMEM_BUDGET_BYTES:
            return bm
    return None


def supports(system: BandedSystem, *, block_m: int | None = None) -> tuple:
    """(ok, reason). Used by ``plan(backend="auto")`` for fallback."""
    if system.periodic and system.mode == "batch":
        return False, ("no Pallas kernel for periodic per-system-LHS solves; "
                       "use backend='reference'")
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    if block_m is not None:
        # an explicit block_m must itself fit, or auto would pick pallas
        # only to have check_vmem raise at solve time
        ws = _kcommon.vmem_working_set(system.n, block_m, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws > _kcommon.VMEM_BUDGET_BYTES:
            return False, (f"working set {ws / 2**20:.1f} MiB at block_m="
                           f"{block_m} exceeds VMEM budget "
                           f"({_kcommon.VMEM_BUDGET_BYTES / 2**20:.0f} MiB)")
        return True, f"block_m={block_m}"
    bm = auto_block_m(system)
    if bm is None:
        ws = _kcommon.vmem_working_set(system.n, _BLOCK_M_CANDIDATES[-1],
                                       n_rhs, n_lhs, itemsize=itemsize)
        return False, (f"working set {ws / 2**20:.1f} MiB at block_m="
                       f"{_BLOCK_M_CANDIDATES[-1]} exceeds VMEM budget "
                       f"({_kcommon.VMEM_BUDGET_BYTES / 2**20:.0f} MiB)")
    return True, f"block_m={bm}"


@register_backend("pallas")
class PallasBackend:
    """Interleaved Pallas TPU kernels (``interpret=True`` off-TPU)."""

    def __init__(self, system: BandedSystem, *, block_m: int | None = None,
                 unroll: int = 1, interpret: bool | None = None,
                 method=None, mesh=None, batch_axis=None):
        del method, mesh, batch_axis  # option-set parity with other backends
        ok, why = supports(system, block_m=block_m)
        if not ok:
            raise NotImplementedError(
                f"pallas backend cannot run {system.describe()}: {why}")
        self.system = system
        self.block_m = block_m if block_m is not None else auto_block_m(system)
        self.unroll = unroll
        self.interpret = interpret
        self.stored = self._build_stored()

    def _build_stored(self):
        s = self.system
        if s.mode == "batch":
            from .reference import build_stored
            return build_stored(s)
        if s.bandwidth == 3:
            if s.periodic:
                return _tridiag.periodic_thomas_factor(*s.diagonals)
            return _tridiag.thomas_factor(*s.diagonals)
        if s.periodic:
            return _penta.periodic_penta_factor(*s.diagonals)
        return _penta.penta_factor(*s.diagonals)

    def solve(self, rhs: jax.Array, *, unroll: int | None = None,
              method=None) -> jax.Array:
        del method  # the sweep schedule is fixed by the kernel
        s = self.system
        squeeze = rhs.ndim == 1
        if squeeze:
            rhs = rhs[:, None]
        # no point tiling wider than the (lane-padded) RHS itself — padding
        # up to a 1024-wide tile for a 96-wide batch wastes ~10x the sweeps
        m_pad = -(-rhs.shape[1] // _kcommon.LANE) * _kcommon.LANE
        kw = dict(block_m=min(self.block_m, max(m_pad, _kcommon.LANE)),
                  interpret=self.interpret,
                  unroll=self.unroll if unroll is None else unroll)

        if s.bandwidth == 3:
            if s.mode == "batch":
                st = self.stored
                x = _kops.thomas_batch(st["a"], st["b"], st["c"], rhs, **kw)
            elif s.periodic:
                pf = self.stored
                y = _kops.thomas_constant(pf.factor, rhs, **kw)
                # rank-1 Sherman-Morrison corner correction (paper Eq. 15)
                v_dot_y = y[0] + pf.v_last * y[-1]
                x = y - (v_dot_y * pf.inv_denom_sm) * pf.z[:, None]
            else:
                x = _kops.thomas_constant(self.stored, rhs, **kw)
        else:
            uniform = s.mode == "uniform"
            if s.mode == "batch":
                st = self.stored
                x = _kops.penta_batch(st["a"], st["b"], st["c"], st["d"],
                                      st["e"], rhs, **kw)
            elif s.periodic:
                pf = self.stored
                y = _kops.penta_constant(pf.factor, rhs, uniform=uniform, **kw)
                # rank-4 Woodbury corner correction (4 x M dots)
                w = pf.Minv @ _penta._vty(pf.vcoef, y)
                x = y - jnp.tensordot(pf.Z, w, axes=([1], [0]))
            else:
                x = _kops.penta_constant(self.stored, rhs, uniform=uniform,
                                         **kw)
        return x[:, 0] if squeeze else x
