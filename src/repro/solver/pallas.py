"""``pallas`` backend: the interleaved Pallas TPU kernels from
``repro.kernels``, with VMEM-aware ``(block_m, block_n)`` auto-tuning.

Layout (DESIGN.md §2): the system index M rides the 128-wide lane axis
(one system per lane — the paper's one system per CUDA thread), the
unknown index N is the sequential sweep axis, and the shared LHS sits in a
single VMEM block whose index_map is constant across the grid.

Auto-tuning is a 2-D search (DESIGN.md §2.1).  The resident kernels
(``block_n=None``) are preferred — one pass, minimum HBM traffic — at the
largest lane tile from ``_BLOCK_M_CANDIDATES`` whose working set
(``vmem_working_set``) fits the VMEM budget.  When no resident tile fits
(N too large), constant/uniform systems fall through to the HBM-streamed
split-N kernels (``thomas_streamed`` / ``penta_streamed``): the largest
``(block_m, block_n)`` pair whose *chunked* working set fits.  The VMEM
wall therefore no longer caps N — ``supports()`` keeps returning True and
``plan(backend="auto")`` keeps picking pallas at any N the HBM holds;
only per-system-LHS (batch) solves still hit the wall (streaming their
five per-lane diagonal blocks is an open item, see ROADMAP).

Periodic boundaries: the kernels solve the truncated band; the rank-1
Sherman-Morrison (tridiag) / rank-4 Woodbury (penta) corner corrections are
applied outside the kernel — a handful of O(M) dots, exactly the paper's
"2-kernel pipeline".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import penta as _penta
from repro.kernels import common as _kcommon
from repro.kernels import ops as _kops

from .registry import register_backend, register_pure_backend
from .system import BandedSystem

_BLOCK_M_CANDIDATES = (1024, 512, 256, 128)
_BLOCK_N_CANDIDATES = (2048, 1024, 512, 256)


def _vmem_counts(system: BandedSystem) -> tuple:
    """(n_rhs_blocks, n_lhs_vecs) matching the check_vmem calls in
    repro.kernels.ops for each kernel this backend dispatches to."""
    if system.bandwidth == 3:
        return (6, 0) if system.mode == "batch" else (2, 3)
    return (9, 0) if system.mode == "batch" else (2, 5)


def _carry_rows(system: BandedSystem) -> int:
    """Sweep-state rows the streamed kernels carry across N-chunks."""
    return 1 if system.bandwidth == 3 else 2


def _can_stream(system: BandedSystem) -> bool:
    # batch mode fuses the factorisation over per-lane LHS copies held in
    # VMEM scratch; streaming those is an open item (ROADMAP).
    return system.mode != "batch"


def _lane_cap(system: BandedSystem) -> int | None:
    if system.batch is None:
        return None
    # no point tiling wider than the (lane-padded) batch itself
    return -(-system.batch // _kcommon.LANE) * _kcommon.LANE


def auto_block_m(system: BandedSystem) -> int | None:
    """Largest candidate lane tile whose RESIDENT (full-N) working set fits
    the VMEM budget (None if even the smallest does not fit)."""
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    cap = _lane_cap(system)
    for bm in _BLOCK_M_CANDIDATES:
        if cap is not None and bm > max(cap, _BLOCK_M_CANDIDATES[-1]):
            continue
        ws = _kcommon.vmem_working_set(system.n, bm, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws <= _kcommon.VMEM_BUDGET_BYTES:
            return bm
    return None


def _streamed_fits(system: BandedSystem, block_m: int, block_n: int) -> bool:
    n_rhs, n_lhs = _vmem_counts(system)
    ws = _kcommon.streamed_vmem_working_set(
        block_n, block_m, n_rhs, n_lhs, _carry_rows(system),
        itemsize=jnp.dtype(system.dtype).itemsize)
    return ws <= _kcommon.VMEM_BUDGET_BYTES


def auto_block_n(system: BandedSystem, block_m: int) -> int | None:
    """Largest streamed N-chunk that fits the budget at ``block_m`` (None
    if even the smallest does not fit, or the mode cannot stream)."""
    if not _can_stream(system):
        return None
    for bn in _BLOCK_N_CANDIDATES:
        if _streamed_fits(system, block_m, bn):
            return bn
    return None


def auto_tune(system: BandedSystem, *, block_m: int | None = None,
              block_n: int | None = None) -> tuple | None:
    """Resolve ``(block_m, block_n)``; ``block_n=None`` means resident.

    Resident is preferred (one pass, half the RHS traffic); the streamed
    split-N pair is the fallback that lifts the VMEM wall.  Explicit user
    choices are honoured when they fit, never silently overridden."""
    n_rhs, n_lhs = _vmem_counts(system)
    itemsize = jnp.dtype(system.dtype).itemsize
    if block_n is not None:
        # explicit streaming request
        if not _can_stream(system):
            return None
        for bm in ((block_m,) if block_m is not None else _BLOCK_M_CANDIDATES):
            if _streamed_fits(system, bm, block_n):
                return bm, block_n
        return None
    if block_m is not None:
        ws = _kcommon.vmem_working_set(system.n, block_m, n_rhs, n_lhs,
                                       itemsize=itemsize)
        if ws <= _kcommon.VMEM_BUDGET_BYTES:
            return block_m, None
        bn = auto_block_n(system, block_m)
        return (block_m, bn) if bn is not None else None
    bm = auto_block_m(system)
    if bm is not None:
        return bm, None
    cap = _lane_cap(system)
    for bm in _BLOCK_M_CANDIDATES:
        if cap is not None and bm > max(cap, _BLOCK_M_CANDIDATES[-1]):
            continue
        bn = auto_block_n(system, bm)
        if bn is not None:
            return bm, bn
    return None


def supports(system: BandedSystem, *, block_m: int | None = None,
             block_n: int | None = None) -> tuple:
    """(ok, reason). Used by ``plan(backend="auto")`` for fallback."""
    if system.periodic and system.mode == "batch":
        return False, ("no Pallas kernel for periodic per-system-LHS solves; "
                       "use backend='reference'")
    tuned = auto_tune(system, block_m=block_m, block_n=block_n)
    if tuned is None:
        n_rhs, n_lhs = _vmem_counts(system)
        itemsize = jnp.dtype(system.dtype).itemsize
        bm = block_m if block_m is not None else _BLOCK_M_CANDIDATES[-1]
        if block_n is not None and _can_stream(system):
            # the failing candidate was an explicit streamed request —
            # report the streamed chunk working set, not the resident one
            ws = _kcommon.streamed_vmem_working_set(
                block_n, bm, n_rhs, n_lhs, _carry_rows(system),
                itemsize=itemsize)
            desc = (f"streamed working set {ws / 2**20:.1f} MiB at "
                    f"block_n={block_n}")
            extra = ""
        else:
            ws = _kcommon.vmem_working_set(system.n, bm, n_rhs, n_lhs,
                                           itemsize=itemsize)
            desc = f"working set {ws / 2**20:.1f} MiB"
            extra = ("; streamed split-N kernels for per-system-LHS (batch) "
                     "solves are not implemented" if not _can_stream(system)
                     else "; no streamed (block_m, block_n) pair fits either")
        return False, (f"{desc} exceeds VMEM budget "
                       f"({_kcommon.VMEM_BUDGET_BYTES / 2**20:.0f} "
                       f"MiB){extra}")
    bm, bn = tuned
    if bn is None:
        return True, f"block_m={bm}"
    return True, f"streamed block_m={bm} block_n={bn}"


def build_stored(system: BandedSystem):
    """Factor once into the kernel-facing stored pytree.

    Same factors as the reference backend, except uniform mode is kept
    full-vector — the kernel reads a stacked LHS block."""
    from .reference import build_stored as _ref_build
    return _ref_build(system, scalarize_uniform=False)


def solve_stored(bandwidth: int, mode: str, periodic: bool, stored,
                 rhs: jax.Array, *, block_m: int, block_n: int | None = None,
                 unroll: int = 1,
                 interpret: bool | None = None) -> jax.Array:
    """Pure kernel dispatch given (static meta, stored pytree, rhs).

    ``block_n=None`` dispatches the VMEM-resident kernels; an integer
    selects the HBM-streamed split-N pair (constant/uniform modes)."""
    squeeze = rhs.ndim == 1
    if squeeze:
        rhs = rhs[:, None]
    # no point tiling wider than the (lane-padded) RHS itself — padding
    # up to a 1024-wide tile for a 96-wide batch wastes ~10x the sweeps
    m_pad = -(-rhs.shape[1] // _kcommon.LANE) * _kcommon.LANE
    kw = dict(block_m=min(block_m, max(m_pad, _kcommon.LANE)),
              interpret=interpret, unroll=unroll)
    skw = dict(kw, block_n=block_n)

    if bandwidth == 3:
        if mode == "batch":
            x = _kops.thomas_batch(stored["a"], stored["b"], stored["c"],
                                   rhs, **kw)
        elif periodic:
            pf = stored
            y = _kops.thomas_constant(pf.factor, rhs, **skw)
            # rank-1 Sherman-Morrison corner correction (paper Eq. 15)
            v_dot_y = y[0] + pf.v_last * y[-1]
            x = y - (v_dot_y * pf.inv_denom_sm) * pf.z[:, None]
        else:
            x = _kops.thomas_constant(stored, rhs, **skw)
    else:
        uniform = mode == "uniform"
        if mode == "batch":
            x = _kops.penta_batch(stored["a"], stored["b"], stored["c"],
                                  stored["d"], stored["e"], rhs, **kw)
        elif periodic:
            pf = stored
            y = _kops.penta_constant(pf.factor, rhs, uniform=uniform, **skw)
            # rank-4 Woodbury corner correction (4 x M dots)
            w = pf.Minv @ _penta._vty(pf.vcoef, y)
            x = y - jnp.tensordot(pf.Z, w, axes=([1], [0]))
        else:
            x = _kops.penta_constant(stored, rhs, uniform=uniform, **skw)
    return x[:, 0] if squeeze else x


# -- the pure-function contract (repro.solver.functional) -------------------

def _pure_build(system: BandedSystem, *, block_m: int | None = None,
                block_n: int | None = None, unroll: int = 1,
                interpret: bool | None = None, **_ignored):
    no_kernel = system.periodic and system.mode == "batch"
    tuned = None if no_kernel else auto_tune(system, block_m=block_m,
                                             block_n=block_n)
    if tuned is None:
        _, why = supports(system, block_m=block_m, block_n=block_n)
        raise NotImplementedError(
            f"pallas backend cannot run {system.describe()}: {why}")
    bm, bn = tuned
    return (build_stored(system),
            {"block_m": bm, "block_n": bn, "unroll": unroll,
             "interpret": interpret})


def _pure_solve(meta, stored, rhs):
    return solve_stored(meta.bandwidth, meta.mode, meta.periodic, stored, rhs,
                        block_m=meta.opt("block_m"),
                        block_n=meta.opt("block_n"),
                        unroll=meta.opt("unroll", 1),
                        interpret=meta.opt("interpret"))


def _pure_transpose(meta, stored, rhs):
    # The adjoint reuses the SAME stored factor via the reference transposed
    # sweeps (A^T = U^T L^T from the forward's vectors) — transposed Pallas
    # kernels are not needed for correctness, only a future perf item.
    from .reference import transpose_solve_stored
    return transpose_solve_stored(meta.bandwidth, meta.mode, meta.periodic,
                                  meta.n, stored, rhs)


register_pure_backend("pallas", build=_pure_build, solve=_pure_solve,
                      transpose_solve=_pure_transpose)


@register_backend("pallas")
class PallasBackend:
    """Interleaved Pallas TPU kernels (``interpret=True`` off-TPU).

    Thin shim over ``factorize``/``solve``: holds a ``Factorization`` whose
    static meta froze the auto-tuned ``block_m``, and routes solves through
    the differentiable ``custom_vjp`` entry point.
    """

    def __init__(self, system: BandedSystem, *, block_m: int | None = None,
                 block_n: int | None = None, unroll: int = 1,
                 interpret: bool | None = None,
                 method=None, mesh=None, batch_axis=None):
        del method, mesh, batch_axis  # option-set parity with other backends
        from .functional import factorize
        self.system = system
        self.fact = factorize(system, backend="pallas", block_m=block_m,
                              block_n=block_n, unroll=unroll,
                              interpret=interpret)
        self.block_m = self.fact.meta.opt("block_m")
        self.block_n = self.fact.meta.opt("block_n")
        self.unroll = unroll
        self.interpret = interpret
        self.stored = self.fact.stored

    def solve(self, rhs: jax.Array, *, unroll: int | None = None,
              method=None) -> jax.Array:
        del method  # the sweep schedule is fixed by the kernel
        from .autodiff import solve as _solve
        from .functional import with_options
        fact = self.fact
        if unroll is not None:
            fact = with_options(fact, unroll=unroll)
        return _solve(fact, rhs)
