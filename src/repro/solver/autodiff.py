"""Differentiable solves: ``jax.custom_vjp`` over the pure ``solve``.

For x = A^{-1} d the VJP is classical implicit differentiation:

    lambda   = A^{-T} g                 (one TRANSPOSED banded solve)
    bar(d)   = lambda
    bar(A)   = -lambda x^T    =>    bar(diag_k)[i] = -sum_m lambda[i,m]
                                                      * x[(i+k) mod N, m]

Two properties make this the paper-faithful adjoint:

  * The transposed solve REUSES the forward ``Factorization``'s stored
    fields (``repro.core.{thomas,penta}_solve_t``: A = L·U means
    A^T = U^T·L^T from the same O(k·N) vectors) — no second copy of the
    band factor, so the ~75 %/~83 % storage saving covers the backward
    pass, and one factorization serves the forward solve, the adjoint
    solve, and every step of a scanned time loop.  (Periodic operators
    additionally store the transposed corner aux ``zt``/``Zt`` — same
    O(N)-sized vectors as the forward's ``z``/``Z``, solved once at factor
    time.)  Each backend supplies its own transpose hook: the ``pallas``
    backend runs the sweep engine's TRANSPOSED Pallas kernels (resident
    or HBM-streamed, matching the forward's tuned blocks — large-N
    gradients never fall back to host-shaped reference sweeps), while
    ``reference``/``sharded`` run the ``repro.core`` transposed scans.
  * Cotangents flow to the spec's vector-valued ``diagonals`` leaves (the
    carriers a PDE-constrained optimisation differentiates), while the
    derived ``stored`` factor leaves get zero cotangent.  Because the
    stored factor is an exact function of the diagonals, assigning the
    whole dA-cotangent to the diagonals keeps total gradients correct for
    any upstream parameterisation (theta -> diagonals -> factor -> x).

``bar(diag_k)`` sums over the system axis M when the LHS is shared
(``constant``/``uniform``/``batch`` specs all carry (N,) diagonals — in
batch mode the spec is tiled at factor time, so the sum is the gradient of
the shared spec).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .functional import Factorization, solve_impl, transpose_solve

_OFFSETS = {3: (-1, 0, 1), 5: (-2, -1, 0, 1, 2)}


def diagonal_cotangents(meta, lam: jax.Array, x: jax.Array) -> tuple:
    """bar(diag_k)[i] = -sum_m lam[i, m] * x[(i + off_k) mod N, m].

    Matrix row i holds ``diag_k[i]`` at column i + off_k (offsets sub-most
    first: -1..1 for tridiag, -2..2 for penta).  ``periodic`` wraps the
    column index (the corner entries of the circulant band); Dirichlet
    zeroes the rows whose column would fall outside the matrix — those spec
    entries are outside the operator, so their cotangent is exactly 0.
    """
    sum_axes = tuple(range(1, lam.ndim))
    cots = []
    for off in _OFFSETS[meta.bandwidth]:
        xs = jnp.roll(x, -off, axis=0)
        if not meta.periodic and off > 0:
            xs = xs.at[-off:].set(0)
        elif not meta.periodic and off < 0:
            xs = xs.at[:-off].set(0)
        bar = -(lam * xs)
        cots.append(bar.sum(axis=sum_axes) if sum_axes else bar)
    return tuple(cots)


@jax.custom_vjp
def solve(factorization: Factorization, rhs: jax.Array) -> jax.Array:
    """Pure differentiable solve: ``A x = rhs`` -> x, rhs (N,) or (N, M).

    Jittable and vmappable (stack factorizations for the multi-LHS case);
    ``jax.grad`` flows to ``rhs`` and to ``factorization.diagonals`` via
    one transposed solve on the SAME stored factor.
    """
    return solve_impl(factorization, rhs)


def _solve_fwd(factorization, rhs):
    x = solve_impl(factorization, rhs)
    # residuals: the factorization (reused for the transposed solve) and the
    # primal solution (enters bar(A) = -lambda x^T). No extra LHS copies.
    return x, (factorization, x)


def _solve_bwd(residuals, g):
    factorization, x = residuals
    lam = transpose_solve(factorization, g)
    bar_fact = dataclasses.replace(
        factorization,
        diagonals=diagonal_cotangents(factorization.meta, lam, x),
        stored=jax.tree_util.tree_map(jnp.zeros_like, factorization.stored),
    )
    return bar_fact, lam


solve.defvjp(_solve_fwd, _solve_bwd)
