"""Backend registry for ``repro.solver``.

A backend is a class with the contract::

    class MyBackend:
        def __init__(self, system: BandedSystem, **opts): ...
        stored: Any                      # factor / LHS pytree held by the plan
        def solve(self, rhs, **kw): ...  # (N, M) or (N,) interleaved RHS -> x

Register with::

    @register_backend("mybackend")
    class MyBackend: ...

Later PRs (caching, async, new accelerators) plug in here without touching
the front-end: ``plan(system, backend="mybackend")`` just works.
"""

from __future__ import annotations

_REGISTRY: dict = {}


def register_backend(name: str):
    """Class decorator: register a solver backend under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver backend {name!r}; available: "
            f"{available_backends()}") from None


def available_backends() -> list:
    return sorted(_REGISTRY)
