"""Backend registry for ``repro.solver``.

Two registration surfaces:

1. The *class* registry (``register_backend``) — what ``plan(...)`` resolves.
   A backend is a class with the contract::

       class MyBackend:
           def __init__(self, system: BandedSystem, **opts): ...
           stored: Any                      # factor / LHS pytree held by the plan
           def solve(self, rhs, **kw): ...  # (N, M) or (N,) interleaved RHS -> x

2. The *pure-function* registry (``register_pure_backend``) — what the
   transformation-native ``factorize``/``solve`` front-end resolves
   (``repro.solver.functional``).  A pure backend is three functions of
   plain pytrees + static meta, so solves cross ``jit``/``vmap``/``grad``/
   ``lax.scan`` boundaries::

       build(system, **opts) -> (stored, options)   # factor once
       solve(meta, stored, rhs) -> x                # pure, jittable
       transpose_solve(meta, stored, rhs) -> x      # adjoint, same stored

Register with::

    @register_backend("mybackend")
    class MyBackend: ...

    register_pure_backend("mybackend", build=..., solve=...,
                          transpose_solve=...)

Later PRs (caching, async, new accelerators) plug in here without touching
the front-end: ``plan(system, backend="mybackend")`` just works, and
registering the pure hooks makes ``factorize(system, backend="mybackend")``
work too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

_REGISTRY: dict = {}
_PURE_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class PureBackend:
    """The pure-function contract behind ``factorize``/``solve``."""

    name: str
    build: Callable[..., tuple]          # (system, **opts) -> (stored, options)
    solve: Callable[..., Any]            # (meta, stored, rhs) -> x
    transpose_solve: Callable[..., Any]  # (meta, stored, rhs) -> x  (A^T x = rhs)


def register_pure_backend(name: str, *, build, solve, transpose_solve):
    """Register the pure factor/solve/transpose functions for ``name``."""
    _PURE_REGISTRY[name] = PureBackend(name=name, build=build, solve=solve,
                                       transpose_solve=transpose_solve)
    return _PURE_REGISTRY[name]


def get_pure_backend(name: str) -> PureBackend:
    """The pure hooks behind ``factorize``/``solve`` for ``name``
    (KeyError with the available names for class-only registrations)."""
    try:
        return _PURE_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"backend {name!r} has no pure factorize/solve registration; "
            f"available: {sorted(_PURE_REGISTRY)} "
            "(class-only backends work through plan(), not factorize())"
        ) from None


def available_pure_backends() -> list:
    """Sorted names of every pure-registered backend — what ``factorize``
    accepts, and what ``repro.analysis.tracecheck`` enumerates so a newly
    registered backend is jit-contract-checked automatically."""
    return sorted(_PURE_REGISTRY)


def register_backend(name: str):
    """Class decorator: register a solver backend under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str):
    """The backend class registered under ``name`` (what ``plan`` uses)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver backend {name!r}; available: "
            f"{available_backends()}") from None


def available_backends() -> list:
    """Sorted names of every class-registered backend."""
    return sorted(_REGISTRY)
