"""``reference`` backend: pure-JAX ``lax.scan`` sweeps from ``repro.core``.

This is the portable oracle every other backend is tested against.  The
factor/solve logic used to live inside ``repro.core.banded``'s operators;
it now lives here so that the deprecated operators, the ``sharded``
backend, and the front-end all share one implementation.

Three module-level functions carry the state machine so they can be reused
outside the class (e.g. inside ``shard_map`` bodies, which need pure
functions of (static meta, stored pytree, rhs)):

  * ``build_stored(system)``   — factor once (constant/uniform) or tile the
    per-system LHS copies (batch).
  * ``expand_uniform(...)``    — re-broadcast the scalar diagonal of a
    uniform-mode factor back to a vector for the sweep.
  * ``solve_stored(...)``      — run the solve given meta + stored + rhs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import penta as _penta
from repro.core import tridiag as _tridiag

from .registry import register_backend
from .system import BandedSystem


def build_stored(system: BandedSystem, *, method: str = "scan"):
    """Factor (constant/uniform) or materialise per-system copies (batch)."""
    n, diags, dtype = system.n, system.diagonals, system.dtype

    if system.mode == "batch":
        m = system.batch
        tile = lambda v: (jnp.broadcast_to(v[:, None], (n, m))
                          + jnp.zeros((n, m), dtype))
        return {k: tile(v) for k, v in zip(system.diagonal_names, diags)}

    if system.bandwidth == 3:
        if system.periodic:
            f = _tridiag.periodic_thomas_factor(*diags, method=method)
        else:
            f = _tridiag.thomas_factor(*diags, method=method)
        if system.mode == "uniform":
            # all-equal diagonals: the `a` vector inside the factor is a
            # scalar broadcast — store it as 0-d (O(2N) factor storage).
            if system.periodic:
                f = f._replace(factor=f.factor._replace(a=f.factor.a[1]))
            else:
                f = f._replace(a=f.a[1])
        return f

    if system.periodic:
        f = _penta.periodic_penta_factor(*diags)
    else:
        f = _penta.penta_factor(*diags)
    if system.mode == "uniform":
        # cuPentUniformBatch: drop the eps (= a) vector -> scalar.
        if system.periodic:
            f = f._replace(factor=f.factor._replace(eps=f.factor.eps[2]))
        else:
            f = f._replace(eps=f.eps[2])
    return f


def expand_uniform(bandwidth: int, periodic: bool, n: int, stored):
    """Uniform mode stores one diagonal as a scalar; expand it for solving."""
    f = stored
    if bandwidth == 3:
        if periodic:
            inner = f.factor
            a = jnp.full((n,), inner.a, inner.inv_denom.dtype).at[0].set(0)
            return f._replace(factor=inner._replace(a=a))
        a = jnp.full((n,), f.a, f.inv_denom.dtype).at[0].set(0)
        return f._replace(a=a)

    def fix(inner):
        eps = jnp.full((n,), inner.eps, inner.beta.dtype)
        eps = eps.at[jnp.array([0, 1])].set(0)
        return inner._replace(eps=eps)

    if periodic:
        return f._replace(factor=fix(f.factor))
    return fix(f)


def solve_stored(bandwidth: int, mode: str, periodic: bool, n: int, stored,
                 rhs: jax.Array, *, method: str = "scan",
                 unroll: int = 1) -> jax.Array:
    """Solve given (static meta, stored pytree, rhs). rhs: (N,) or (N, M)."""
    if bandwidth == 3:
        if mode == "batch":
            s = stored
            if periodic:
                def one(a, b, c, d1):
                    pf = _tridiag.periodic_thomas_factor(a, b, c, method=method)
                    return _tridiag.periodic_thomas_solve(pf, d1, method=method)
                return jax.vmap(one, in_axes=1, out_axes=1)(
                    s["a"], s["b"], s["c"], rhs)
            # cuThomasBatch semantics: factor fused into the solve, every call.
            return _tridiag.thomas_factor_solve(s["a"], s["b"], s["c"], rhs,
                                                method=method)
        f = (expand_uniform(bandwidth, periodic, n, stored)
             if mode == "uniform" else stored)
        if periodic:
            return _tridiag.periodic_thomas_solve(f, rhs, method=method,
                                                  unroll=unroll)
        return _tridiag.thomas_solve(f, rhs, method=method, unroll=unroll)

    if mode == "batch":
        s = stored
        if periodic:
            def one(a, b, c, d, e, r):
                pf = _penta.periodic_penta_factor(a, b, c, d, e)
                return _penta.periodic_penta_solve(pf, r, method=method)
            return jax.vmap(one, in_axes=1, out_axes=1)(
                s["a"], s["b"], s["c"], s["d"], s["e"], rhs)
        return _penta.penta_factor_solve(s["a"], s["b"], s["c"], s["d"],
                                         s["e"], rhs, method=method)
    f = (expand_uniform(bandwidth, periodic, n, stored)
         if mode == "uniform" else stored)
    if periodic:
        return _penta.periodic_penta_solve(f, rhs, method=method,
                                           unroll=unroll)
    return _penta.penta_solve(f, rhs, method=method, unroll=unroll)


@register_backend("reference")
class ReferenceBackend:
    """Pure-JAX scan backend (factor once, broadcast to every RHS lane)."""

    def __init__(self, system: BandedSystem, *, method: str = "scan",
                 unroll: int = 1, block_m=None, interpret=None, mesh=None,
                 batch_axis=None):
        # block_m / interpret / mesh are accepted (and ignored) so that
        # callers can flip `backend=` without changing the option set.
        del block_m, interpret, mesh, batch_axis
        self.system = system
        self.method = method
        self.unroll = unroll
        self.stored = build_stored(system, method=method)

    def factor_for_solve(self):
        if self.system.mode == "uniform":
            return expand_uniform(self.system.bandwidth, self.system.periodic,
                                  self.system.n, self.stored)
        return self.stored

    def solve(self, rhs: jax.Array, *, method: str | None = None,
              unroll: int | None = None) -> jax.Array:
        s = self.system
        return solve_stored(s.bandwidth, s.mode, s.periodic, s.n, self.stored,
                            rhs, method=method or self.method,
                            unroll=self.unroll if unroll is None else unroll)
