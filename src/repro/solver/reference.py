"""``reference`` backend: pure-JAX ``lax.scan`` sweeps from ``repro.core``.

This is the portable oracle every other backend is tested against.  The
factor/solve logic used to live inside ``repro.core.banded``'s operators;
it now lives here so that the deprecated operators, the ``sharded``
backend, and the front-end all share one implementation.

Module-level functions carry the state machine so they can be reused
outside the class (inside ``shard_map`` bodies and as the pure-function
backend behind ``repro.solver.functional`` — both need pure functions of
(static meta, stored pytree, rhs)):

  * ``build_stored(system)``   — factor once (constant/uniform) or tile the
    per-system LHS copies (batch).
  * ``expand_uniform(...)``    — re-broadcast the scalar diagonal of a
    uniform-mode factor back to a vector for the sweep.
  * ``solve_stored(...)``      — run the solve given meta + stored + rhs.
  * ``transpose_solve_stored(...)`` — solve A^T x = rhs from the SAME
    stored factor (the adjoint sweeps; DESIGN.md §5.1).  Also the
    transpose hook of the ``sharded`` pure backend (same stored-factor
    layout) and the oracle the ``pallas`` backend's own transposed
    kernels are tested against — pallas adjoints run on Pallas now
    (``repro.solver.pallas.transpose_solve_stored``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import penta as _penta
from repro.core import tridiag as _tridiag

from .registry import register_backend, register_pure_backend
from .system import BandedSystem


def build_stored(system: BandedSystem, *, method: str = "scan",
                 scalarize_uniform: bool = True):
    """Factor (constant/uniform) or materialise per-system copies (batch).

    ``scalarize_uniform=False`` keeps uniform-mode factors full-vector (the
    pallas backend wants them that way for its stacked LHS block)."""
    n, diags, dtype = system.n, system.diagonals, system.dtype

    if system.mode == "batch":
        m = system.batch
        tile = lambda v: (jnp.broadcast_to(v[:, None], (n, m))
                          + jnp.zeros((n, m), dtype))
        return {k: tile(v) for k, v in zip(system.diagonal_names, diags)}

    uniform = system.mode == "uniform" and scalarize_uniform

    if system.bandwidth == 3:
        if system.periodic:
            f = _tridiag.periodic_thomas_factor(*diags, method=method)
        else:
            f = _tridiag.thomas_factor(*diags, method=method)
        if uniform:
            # all-equal diagonals: the `a` vector inside the factor is a
            # scalar broadcast — store it as 0-d (O(2N) factor storage).
            if system.periodic:
                f = f._replace(factor=f.factor._replace(a=f.factor.a[1]))
            else:
                f = f._replace(a=f.a[1])
        return f

    if system.periodic:
        f = _penta.periodic_penta_factor(*diags)
    else:
        f = _penta.penta_factor(*diags)
    if uniform:
        # cuPentUniformBatch: drop the eps (= a) vector -> scalar.
        if system.periodic:
            f = f._replace(factor=f.factor._replace(eps=f.factor.eps[2]))
        else:
            f = f._replace(eps=f.eps[2])
    return f


def expand_uniform(bandwidth: int, periodic: bool, n: int, stored):
    """Uniform mode stores one diagonal as a scalar; expand it for solving."""
    f = stored
    if bandwidth == 3:
        if periodic:
            inner = f.factor
            a = jnp.full((n,), inner.a, inner.inv_denom.dtype).at[0].set(0)
            return f._replace(factor=inner._replace(a=a))
        a = jnp.full((n,), f.a, f.inv_denom.dtype).at[0].set(0)
        return f._replace(a=a)

    def fix(inner):
        eps = jnp.full((n,), inner.eps, inner.beta.dtype)
        eps = eps.at[jnp.array([0, 1])].set(0)
        return inner._replace(eps=eps)

    if periodic:
        return f._replace(factor=fix(f.factor))
    return fix(f)


def solve_stored(bandwidth: int, mode: str, periodic: bool, n: int, stored,
                 rhs: jax.Array, *, method: str = "scan",
                 unroll: int = 1) -> jax.Array:
    """Solve given (static meta, stored pytree, rhs). rhs: (N,) or (N, M)."""
    if bandwidth == 3:
        if mode == "batch":
            s = stored
            if periodic:
                def one(a, b, c, d1):
                    pf = _tridiag.periodic_thomas_factor(a, b, c, method=method)
                    return _tridiag.periodic_thomas_solve(pf, d1, method=method)
                return jax.vmap(one, in_axes=1, out_axes=1)(
                    s["a"], s["b"], s["c"], rhs)
            # cuThomasBatch semantics: factor fused into the solve, every call.
            return _tridiag.thomas_factor_solve(s["a"], s["b"], s["c"], rhs,
                                                method=method)
        f = (expand_uniform(bandwidth, periodic, n, stored)
             if mode == "uniform" else stored)
        if periodic:
            return _tridiag.periodic_thomas_solve(f, rhs, method=method,
                                                  unroll=unroll)
        return _tridiag.thomas_solve(f, rhs, method=method, unroll=unroll)

    if mode == "batch":
        s = stored
        if periodic:
            def one(a, b, c, d, e, r):
                pf = _penta.periodic_penta_factor(a, b, c, d, e)
                return _penta.periodic_penta_solve(pf, r, method=method)
            return jax.vmap(one, in_axes=1, out_axes=1)(
                s["a"], s["b"], s["c"], s["d"], s["e"], rhs)
        return _penta.penta_factor_solve(s["a"], s["b"], s["c"], s["d"],
                                         s["e"], rhs, method=method)
    f = (expand_uniform(bandwidth, periodic, n, stored)
         if mode == "uniform" else stored)
    if periodic:
        return _penta.periodic_penta_solve(f, rhs, method=method,
                                           unroll=unroll)
    return _penta.penta_solve(f, rhs, method=method, unroll=unroll)


def _expand_if_scalarized(bandwidth: int, periodic: bool, n: int, stored):
    """Expand a uniform-scalarized factor; pass full factors through.

    The reference backend stores uniform factors with a 0-d ``a``/``eps``
    (the paper's O((k-1)N) saving); the pallas backend keeps them full.
    Dispatch on the leaf rank so one transpose path serves both.
    """
    if bandwidth == 3:
        leaf = stored.factor.a if periodic else stored.a
    else:
        leaf = stored.factor.eps if periodic else stored.eps
    if jnp.ndim(leaf) == 0:
        return expand_uniform(bandwidth, periodic, n, stored)
    return stored


def transpose_solve_stored(bandwidth: int, mode: str, periodic: bool, n: int,
                           stored, rhs: jax.Array, *, method: str = "scan",
                           unroll: int = 1) -> jax.Array:
    """Solve A^T x = rhs from the SAME stored factor (the adjoint sweeps).

    constant/uniform: ``repro.core.{thomas,penta}_solve_t`` — A = L·U means
    A^T = U^T·L^T from the forward's factor vectors, so the backward pass
    adds ZERO LHS storage.  batch mode has no stored factor (cuThomasBatch
    semantics re-factor every call), so the transposed diagonals are formed
    by rolling the per-system copies (the factor routines zero the entries
    rolled across the Dirichlet boundary).
    """
    if mode == "batch":
        s = stored
        if bandwidth == 3:
            at = jnp.roll(s["c"], 1, axis=0)
            ct = jnp.roll(s["a"], -1, axis=0)
            if periodic:
                def one(a, b, c, r):
                    pf = _tridiag.periodic_thomas_factor(a, b, c,
                                                         method=method)
                    return _tridiag.periodic_thomas_solve(pf, r,
                                                          method=method)
                return jax.vmap(one, in_axes=1, out_axes=1)(
                    at, s["b"], ct, rhs)
            return _tridiag.thomas_factor_solve(at, s["b"], ct, rhs,
                                                method=method)
        at = jnp.roll(s["e"], 2, axis=0)
        bt = jnp.roll(s["d"], 1, axis=0)
        dt = jnp.roll(s["b"], -1, axis=0)
        et = jnp.roll(s["a"], -2, axis=0)
        if periodic:
            def one(a, b, c, d, e, r):
                pf = _penta.periodic_penta_factor(a, b, c, d, e)
                return _penta.periodic_penta_solve(pf, r, method=method)
            return jax.vmap(one, in_axes=1, out_axes=1)(
                at, bt, s["c"], dt, et, rhs)
        return _penta.penta_factor_solve(at, bt, s["c"], dt, et, rhs,
                                         method=method)

    f = _expand_if_scalarized(bandwidth, periodic, n, stored)
    if bandwidth == 3:
        if periodic:
            return _tridiag.periodic_thomas_solve_t(f, rhs, method=method,
                                                    unroll=unroll)
        return _tridiag.thomas_solve_t(f, rhs, method=method, unroll=unroll)
    if periodic:
        return _penta.periodic_penta_solve_t(f, rhs, method=method,
                                             unroll=unroll)
    return _penta.penta_solve_t(f, rhs, method=method, unroll=unroll)


# -- the pure-function contract (repro.solver.functional) -------------------

def _pure_build(system: BandedSystem, *, method: str = "scan",
                unroll: int = 1, **_ignored):
    return (build_stored(system, method=method),
            {"method": method, "unroll": unroll})


def _pure_solve(meta, stored, rhs):
    return solve_stored(meta.bandwidth, meta.mode, meta.periodic, meta.n,
                        stored, rhs, method=meta.opt("method", "scan"),
                        unroll=meta.opt("unroll", 1))


def _pure_transpose(meta, stored, rhs):
    return transpose_solve_stored(meta.bandwidth, meta.mode, meta.periodic,
                                  meta.n, stored, rhs,
                                  method=meta.opt("method", "scan"),
                                  unroll=meta.opt("unroll", 1))


register_pure_backend("reference", build=_pure_build, solve=_pure_solve,
                      transpose_solve=_pure_transpose)


@register_backend("reference")
class ReferenceBackend:
    """Pure-JAX scan backend (factor once, broadcast to every RHS lane).

    Thin shim over the pure ``factorize``/``solve`` functions: the class
    holds a ``Factorization`` pytree and its ``solve`` routes through the
    ``custom_vjp``-wrapped entry point, so ``plan(...).solve`` is
    differentiable too.
    """

    def __init__(self, system: BandedSystem, *, method: str = "scan",
                 unroll: int = 1, block_m=None, block_n=None, interpret=None,
                 mesh=None, batch_axis=None, kernels=None):
        # block_m / block_n / interpret / mesh / kernels are accepted (and
        # ignored) so callers can flip `backend=` without changing the
        # option set.
        del block_m, block_n, interpret, mesh, batch_axis, kernels
        from .functional import factorize
        self.system = system
        self.method = method
        self.unroll = unroll
        self.fact = factorize(system, backend="reference", method=method,
                              unroll=unroll)
        self.stored = self.fact.stored

    def factor_for_solve(self):
        if self.system.mode == "uniform":
            return expand_uniform(self.system.bandwidth, self.system.periodic,
                                  self.system.n, self.stored)
        return self.stored

    def solve(self, rhs: jax.Array, *, method: str | None = None,
              unroll: int | None = None) -> jax.Array:
        from .autodiff import solve as _solve
        from .functional import with_options
        fact = self.fact
        if method is not None or unroll is not None:
            fact = with_options(fact, method=method, unroll=unroll)
        return _solve(fact, rhs)
