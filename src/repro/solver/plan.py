"""``plan(system, backend=...) -> Plan`` — the repro.solver front-end.

``backend`` is a registry name (``reference`` / ``pallas`` / ``sharded`` /
any later registration) or ``"auto"``:

  * auto picks ``pallas`` when the kernel supports the system AND its
    working set fits the VMEM budget (``interpret=True`` is applied
    automatically off-TPU by the kernel wrappers, so auto means
    pallas-interpret on CPU and compiled pallas on TPU);
  * otherwise auto falls back to ``reference`` instead of raising —
    oversize working sets degrade gracefully.

Backend-specific options ride as keyword arguments (``block_m``,
``unroll``, ``interpret``, ``method``, ``mesh``, ``batch_axis``); every
backend accepts the full option set and ignores what it does not use, so a
sweep can flip ``backend=`` with one argument.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from .registry import get_backend
from .system import BandedSystem


def _nbytes(tree: Any) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """A prepared solve: spec + resolved backend + backend state."""

    system: BandedSystem
    backend: str
    impl: Any

    def solve(self, rhs, **kw) -> jax.Array:
        """rhs: (N,) or (N, M) interleaved batch -> x of the same shape."""
        return self.impl.solve(rhs, **kw)

    def storage_bytes(self, *, rhs_batch: int | None = None,
                      itemsize: int = 4) -> dict:
        """Actual bytes held by the plan's LHS state, so the paper's
        ~75 % / ~83 % reduction claims are measured, not quoted."""
        lhs = _nbytes(self.impl.stored)
        out = {"lhs_bytes": lhs, "mode": self.system.mode,
               "n": self.system.n, "backend": self.backend}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.system.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out


def select_backend(system: BandedSystem, *, block_m: int | None = None) -> str:
    """The ``backend="auto"`` policy: pallas when it fits, else reference."""
    from . import pallas as _pallas

    ok, _why = _pallas.supports(system, block_m=block_m)
    return "pallas" if ok else "reference"


# legacy spelling used by the pre-frontend pde layer
_ALIASES = {"core": "reference"}


def plan(system: BandedSystem, backend: str = "auto", **opts) -> Plan:
    """Prepare a solve for ``system`` on ``backend``.

    >>> p = plan(BandedSystem.tridiag(-s, 1 + 2*s, -s, n=512, periodic=True))
    >>> x = p.solve(rhs)            # rhs: (N, M) interleaved
    """
    backend = _ALIASES.get(backend, backend)
    if backend == "auto":
        backend = select_backend(system, block_m=opts.get("block_m"))
    impl = get_backend(backend)(system, **opts)
    return Plan(system=system, backend=backend, impl=impl)
