"""``plan(system, backend=...) -> Plan`` — the stateful repro.solver shim.

Since the transformation-native redesign the canonical API is the pure pair
``factorize(system) -> Factorization`` / ``solve(factorization, rhs)``
(``repro.solver.functional`` — jittable, vmappable, differentiable).
``Plan`` remains as a thin convenience shim: it resolves the backend,
builds the ``Factorization`` (held by the backend class as ``impl.fact``)
and forwards ``Plan.solve`` to the same ``custom_vjp``-wrapped solve, so
plan-based call sites get identical numerics AND gradients.

``backend`` is a registry name (``reference`` / ``pallas`` / ``sharded`` /
any later registration) or ``"auto"``:

  * auto picks ``pallas`` when the kernel supports the system AND its
    working set fits the VMEM budget (``interpret=True`` is applied
    automatically off-TPU by the kernel wrappers, so auto means
    pallas-interpret on CPU and compiled pallas on TPU);
  * otherwise auto falls back to ``reference`` instead of raising —
    oversize working sets degrade gracefully.

Backend-specific options ride as keyword arguments (``block_m``,
``block_n``, ``unroll``, ``interpret``, ``method``, ``mesh``,
``batch_axis``, ``kernels``); every backend accepts the full option set
and ignores what it does not use, so a sweep can flip ``backend=`` with
one argument.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .functional import ALIASES, Factorization, select_backend
from .registry import get_backend
from .system import BandedSystem


def _nbytes(tree: Any) -> int:
    # host-side: leaf shapes/itemsizes are static metadata, never traced
    return int(sum(np.prod(l.shape) * l.dtype.itemsize  # speclint: allow-concretize
                   for l in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """A prepared solve: spec + resolved backend + backend state.

    A ``Plan`` is host-side state (NOT a pytree — it does not cross
    ``jit`` boundaries itself); its ``factorization`` property exposes the
    transformation-native pytree underneath, and ``Plan.solve`` routes
    through the same ``custom_vjp``-wrapped solve, so plan-based call
    sites get identical numerics and gradients."""

    system: BandedSystem
    backend: str
    impl: Any

    @property
    def factorization(self) -> Factorization | None:
        """The pytree behind this plan (None for class-only backends)."""
        return getattr(self.impl, "fact", None)

    def solve(self, rhs, **kw) -> jax.Array:
        """rhs: (N,) or (N, M) interleaved batch -> x of the same shape."""
        return self.impl.solve(rhs, **kw)

    def storage_bytes(self, *, rhs_batch: int | None = None,
                      itemsize: int | None = None) -> dict:
        """Actual bytes held by the plan's LHS state, so the paper's
        ~75 % / ~83 % reduction claims are measured, not quoted.

        ``itemsize`` defaults to the system dtype's width (fp64 RHS batches
        are no longer under-counted by a hardcoded 4)."""
        if itemsize is None:
            itemsize = jnp.dtype(self.system.dtype).itemsize
        lhs = _nbytes(self.impl.stored)
        out = {"lhs_bytes": lhs, "mode": self.system.mode,
               "n": self.system.n, "backend": self.backend}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.system.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out


# legacy spelling used by the pre-frontend pde layer (re-exported for
# compat; the source of truth lives in repro.solver.functional)
_ALIASES = ALIASES


def plan(system: BandedSystem, backend: str = "auto", **opts) -> Plan:
    """Prepare a solve for ``system`` on ``backend``.

    ``backend`` resolves at call time (``"auto"`` -> pallas when a kernel
    fits, else reference); ``**opts`` is the union option set the module
    docstring lists — resolution (auto-tuning, mesh defaulting, the
    sharded backend's per-shard kernel policy) happens here, outside any
    trace.

    >>> p = plan(BandedSystem.tridiag(-s, 1 + 2*s, -s, n=512, periodic=True))
    >>> x = p.solve(rhs)            # rhs: (N, M) interleaved
    """
    backend = _ALIASES.get(backend, backend)
    if backend == "auto":
        backend = select_backend(system, block_m=opts.get("block_m"),
                                 block_n=opts.get("block_n"))
    impl = get_backend(backend)(system, **opts)
    return Plan(system=system, backend=backend, impl=impl)
