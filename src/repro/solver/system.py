"""BandedSystem — the problem spec consumed by ``repro.solver.plan``.

A ``BandedSystem`` is pure data: which banded matrix (bandwidth 3 or 5),
its diagonals (each a scalar or an ``(N,)`` vector), the boundary
condition, and the paper's storage mode:

  * ``constant`` — ONE shared LHS for the whole batch
    (cuThomasConstantBatch / cuPentConstantBatch — the paper's contribution).
  * ``uniform``  — all entries of each diagonal equal
    (cuPentUniformBatch): one stored vector degenerates to a scalar.
  * ``batch``    — per-system LHS copies, factor fused into every solve
    (cuThomasBatch / cuPentBatch, the prior state of the art).

Backends consume the spec via ``repro.solver.plan``; the spec itself never
factors anything.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

MODES = ("constant", "uniform", "batch")
BANDWIDTHS = (3, 5)


def _as_vec(x, n: int, dtype) -> jax.Array:
    x = jnp.asarray(x, dtype=dtype)
    if x.ndim == 0:
        return jnp.full((n,), x, dtype=dtype)
    if x.shape != (n,):
        raise ValueError(f"diagonal has shape {x.shape}, expected ({n},)")
    return x


@dataclasses.dataclass(frozen=True, eq=False)
class BandedSystem:
    """Spec for a batched banded solve with one (conceptual) LHS.

    ``diagonals`` are ordered sub-most first: ``(a, b, c)`` for bandwidth 3
    (``a`` sub, ``b`` main, ``c`` super) and ``(a, b, c, d, e)`` for
    bandwidth 5 (``c`` main), matching the paper's row convention.
    """

    bandwidth: int
    diagonals: tuple
    n: int
    periodic: bool = False
    mode: str = "constant"
    batch: int | None = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.bandwidth not in BANDWIDTHS:
            raise ValueError(f"bandwidth must be one of {BANDWIDTHS}, "
                             f"got {self.bandwidth}")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.mode == "batch" and self.batch is None:
            raise ValueError("mode='batch' requires batch=M "
                             "(number of per-system LHS copies)")
        if self.n < self.bandwidth:
            raise ValueError(f"n={self.n} too small for bandwidth "
                             f"{self.bandwidth}")
        if len(self.diagonals) != self.bandwidth:
            raise ValueError(f"expected {self.bandwidth} diagonals, "
                             f"got {len(self.diagonals)}")

    # -- constructors -------------------------------------------------------

    @classmethod
    def tridiag(cls, a, b, c, *, n: int | None = None, periodic: bool = False,
                mode: str = "constant", batch: int | None = None,
                dtype=jnp.float32) -> "BandedSystem":
        """Tridiagonal system: a x_{i-1} + b x_i + c x_{i+1} = rhs_i."""
        if n is None:
            n = jnp.asarray(b).shape[0]
        diags = tuple(_as_vec(v, n, dtype) for v in (a, b, c))
        return cls(bandwidth=3, diagonals=diags, n=n, periodic=periodic,
                   mode=mode, batch=batch, dtype=dtype)

    @classmethod
    def penta(cls, a, b, c, d, e, *, n: int | None = None,
              periodic: bool = False, mode: str = "constant",
              batch: int | None = None, dtype=jnp.float32) -> "BandedSystem":
        """Pentadiagonal system:
        a x_{i-2} + b x_{i-1} + c x_i + d x_{i+1} + e x_{i+2} = rhs_i."""
        if n is None:
            n = jnp.asarray(c).shape[0]
        diags = tuple(_as_vec(v, n, dtype) for v in (a, b, c, d, e))
        return cls(bandwidth=5, diagonals=diags, n=n, periodic=periodic,
                   mode=mode, batch=batch, dtype=dtype)

    # -- helpers ------------------------------------------------------------

    @property
    def diagonal_names(self) -> tuple:
        return ("a", "b", "c") if self.bandwidth == 3 else ("a", "b", "c", "d", "e")

    def transposed(self) -> "BandedSystem":
        """The spec of A^T: diagonal k of A^T at offset ``off`` is diagonal
        ``-off`` of A rolled by ``off`` (wrap entries land exactly on the
        periodic corners; Dirichlet's rolled-in values sit outside the band
        and are zeroed by the factor routines).

        ``transpose_solve``/``grad`` do NOT use this — they reuse the
        forward factorization (DESIGN.md §5.1).  This spec exists as the
        independent oracle those paths are tested against.
        """
        half = self.bandwidth // 2
        # diagonal at offset s lands at offset -s, rolled by s
        rolled = tuple(jnp.roll(d, s, axis=0) for s, d in
                       zip(range(-half, half + 1), self.diagonals))
        return dataclasses.replace(self, diagonals=rolled[::-1])

    def describe(self) -> str:
        kind = "tridiag" if self.bandwidth == 3 else "penta"
        bc = "periodic" if self.periodic else "dirichlet"
        return f"{kind}/{bc}/{self.mode}/N={self.n}"
