"""repro.solver — the single public entry point for all banded solves.

The paper's contribution is a storage/layout policy (one shared LHS, an
interleaved ``(N, M)`` RHS batch).  This package exposes that policy through
ONE front-end, retargetable across execution backends.

The canonical, transformation-native spelling is the pure pair
(``factorize`` / ``solve``) — the factorization is a pytree that crosses
``jit``/``vmap``/``grad``/``lax.scan`` boundaries, and ``solve`` carries a
``custom_vjp`` whose adjoint reuses the forward factor:

    from repro.solver import BandedSystem, factorize, solve

    system = BandedSystem.tridiag(-s, 1 + 2 * s, -s, n=512, periodic=True)
    fact = factorize(system, backend="auto")   # factor ONCE -> pytree
    x = jax.jit(solve)(fact, rhs)              # rhs: (N,) or (N, M)
    g = jax.grad(lambda r: solve(fact, r).sum())(rhs)   # adjoint, same LHS

The stateful shim remains for convenience (and is itself differentiable):

    p = plan(system, backend="auto")     # reference | pallas | sharded | auto
    x = p.solve(rhs)

Backends live in a registry (see ``registry.register_backend``):

  * ``reference`` — pure-JAX ``lax.scan`` sweeps from ``repro.core``
    (CPU/GPU/TPU portable oracle).
  * ``pallas``    — the interleaved Pallas TPU kernels from
    ``repro.kernels`` with VMEM-aware ``block_m`` auto-tuning
    (``interpret=True`` automatically off-TPU).
  * ``sharded``   — ``shard_map`` over a device mesh: the LHS replicated
    per device (the paper's storage saving, applied per device), the M
    system axis sharded, zero collectives in the solve — and each device
    running the sweep engine's Pallas kernels (resident or HBM-streamed,
    per a tuner sized to the LOCAL shard) on its slice (DESIGN.md §7).

``backend="auto"`` picks ``pallas`` when the kernel working set fits the
VMEM budget and falls back to ``reference`` otherwise (instead of raising).

The traced/static contract (DESIGN.md §5.1) in one line: array data (the
stored factor, the spec diagonals, the RHS) traces as pytree leaves;
everything a compiler must specialise on (bandwidth, N, mode, boundary,
backend name, RESOLVED options — tuned blocks, the concrete mesh) is
hashable static aux data resolved once in ``factorize``, never inside a
trace.  ``MODES`` is the tuple of storage-mode names
(``("constant", "uniform", "batch")`` — the paper's comparison axis).

See DESIGN.md §5 for the full API contract, and README.md for the tour.
"""

from .functional import (Factorization, SolveMeta, factorize,
                         transpose_solve, with_options)
from .plan import Plan, plan
from .registry import (available_backends, get_backend, get_pure_backend,
                       register_backend, register_pure_backend)
from .system import MODES, BandedSystem

# importing the backend modules populates the registries
from . import pallas as _pallas_backend      # noqa: F401,E402
from . import reference as _reference_backend  # noqa: F401,E402
from . import sharded as _sharded_backend    # noqa: F401,E402

# the custom_vjp-wrapped solve (after the backends, so factorize-at-import
# users see a populated registry)
from .autodiff import solve                  # noqa: E402

__all__ = [
    "BandedSystem",
    "Factorization",
    "MODES",
    "Plan",
    "SolveMeta",
    "available_backends",
    "factorize",
    "get_backend",
    "get_pure_backend",
    "plan",
    "register_backend",
    "register_pure_backend",
    "solve",
    "transpose_solve",
    "with_options",
]
