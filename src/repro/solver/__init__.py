"""repro.solver — the single public entry point for all banded solves.

The paper's contribution is a storage/layout policy (one shared LHS, an
interleaved ``(N, M)`` RHS batch).  This package exposes that policy through
ONE front-end, retargetable across execution backends.

The canonical, transformation-native spelling is the pure pair
(``factorize`` / ``solve``) — the factorization is a pytree that crosses
``jit``/``vmap``/``grad``/``lax.scan`` boundaries, and ``solve`` carries a
``custom_vjp`` whose adjoint reuses the forward factor:

    from repro.solver import BandedSystem, factorize, solve

    system = BandedSystem.tridiag(-s, 1 + 2 * s, -s, n=512, periodic=True)
    fact = factorize(system, backend="auto")   # factor ONCE -> pytree
    x = jax.jit(solve)(fact, rhs)              # rhs: (N,) or (N, M)
    g = jax.grad(lambda r: solve(fact, r).sum())(rhs)   # adjoint, same LHS

The stateful shim remains for convenience (and is itself differentiable):

    p = plan(system, backend="auto")     # reference | pallas | sharded | auto
    x = p.solve(rhs)

Backends live in a registry (see ``registry.register_backend``):

  * ``reference`` — pure-JAX ``lax.scan`` sweeps from ``repro.core``
    (CPU/GPU/TPU portable oracle).
  * ``pallas``    — the interleaved Pallas TPU kernels from
    ``repro.kernels`` with VMEM-aware ``block_m`` auto-tuning
    (``interpret=True`` automatically off-TPU).
  * ``sharded``   — ``shard_map`` over a device mesh: the LHS replicated
    per device (the paper's storage saving, applied per device), the M
    system axis sharded, zero collectives in the solve.

``backend="auto"`` picks ``pallas`` when the kernel working set fits the
VMEM budget and falls back to ``reference`` otherwise (instead of raising).

See DESIGN.md §5 for the full API contract.
"""

from .functional import (Factorization, SolveMeta, factorize,
                         transpose_solve, with_options)
from .plan import Plan, plan
from .registry import (available_backends, get_backend, get_pure_backend,
                       register_backend, register_pure_backend)
from .system import MODES, BandedSystem

# importing the backend modules populates the registries
from . import pallas as _pallas_backend      # noqa: F401,E402
from . import reference as _reference_backend  # noqa: F401,E402
from . import sharded as _sharded_backend    # noqa: F401,E402

# the custom_vjp-wrapped solve (after the backends, so factorize-at-import
# users see a populated registry)
from .autodiff import solve                  # noqa: E402

__all__ = [
    "BandedSystem",
    "Factorization",
    "MODES",
    "Plan",
    "SolveMeta",
    "available_backends",
    "factorize",
    "get_backend",
    "get_pure_backend",
    "plan",
    "register_backend",
    "register_pure_backend",
    "solve",
    "transpose_solve",
    "with_options",
]
