"""``sharded`` backend: the paper's single-LHS idea at cluster scale.

One LHS copy per DEVICE (replicated — the paper's storage saving applied
per device), the M system axis sharded across a mesh, zero collectives in
the solve: systems are independent, so each device solves its local slice
of the interleaved batch.

Since the sharded x streamed composition (DESIGN.md §7) each device runs
the sweep engine's Pallas kernels — the SAME ``SweepSpec``-compiled
resident or HBM-streamed pairs the single-device ``pallas`` backend
dispatches — instead of reference scans inside ``shard_map``.  A
per-device tuner (``local_tune``) resolves ``(block_m, block_n)`` against
the LOCAL lane count (``kernels.common.shard_lanes``): resident at the
largest lane tile the VMEM budget allows, falling through to the 2-D
streamed split-N pair past the wall, exactly the single-device policy but
sized to the shard.  Modes with no kernel (periodic x batch) and
pathologically small budgets degrade per-shard to the reference sweeps —
the ``kernels`` option ("auto" | "pallas" | "reference") makes the policy
explicit and ``SolveMeta`` records what was resolved.

For ``mode="batch"`` the per-system LHS copies are sharded *with* their
systems (each device only holds the diagonals of its own slice).  The M
axis is padded to a multiple of the mesh size with identity rows
(``main diagonal = 1``) so padded lanes solve trivially and are sliced off.

The pure-function contract: the resolved ``Mesh`` (hashable) rides in the
``Factorization``'s static meta, so a sharded solve crosses ``jit``/``grad``
/``lax.scan`` like any other — the ``shard_map`` is retraced only when the
mesh itself changes.  The adjoint solve is sharded too: the same
``shard_map`` dispatch runs the engine's TRANSPOSED kernels (or the
reference transposed sweeps when kernels are off) on the SAME stored
factor, so large-N ``grad(solve)`` through a mesh stays on Pallas.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.common import (canonical_storage_dtype, pad_lanes,
                                  shard_lanes)

from .reference import (build_stored, solve_stored, transpose_solve_stored)
from .registry import register_backend, register_pure_backend
from .system import BandedSystem

#: What each shard runs. "auto" = engine Pallas kernels when a SweepSpec
#: serves the mode and fits the per-device budget, else reference sweeps;
#: "pallas" forces the kernels (raising like the pallas backend when it
#: cannot); "reference" keeps the pre-composition scan sweeps.
KERNEL_POLICIES = ("auto", "pallas", "reference")


def default_mesh(axis_name: str = "batch") -> Mesh:
    """1-D mesh over every visible device."""
    return Mesh(np.array(jax.devices()), (axis_name,))


def resolve_mesh(mesh: Mesh | None, batch_axis):
    """(mesh, batch_axis, n_shards) with the defaulting rules of PR 1."""
    if mesh is None:
        mesh = default_mesh()
        batch_axis = mesh.axis_names[0]
    elif batch_axis is None:
        batch_axis = mesh.axis_names[-1]
    axes = batch_axis if isinstance(batch_axis, tuple) else (batch_axis,)
    # host-side: mesh axis sizes are static Python ints, never traced
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))  # speclint: allow-concretize
    return mesh, batch_axis, n_shards


def local_system(system: BandedSystem, n_shards: int) -> BandedSystem:
    """The spec one DEVICE sees: same N (the sweep axis is never sharded),
    batch-mode lane count divided by the mesh (after mesh padding).

    This is what the per-device tuner sizes against — the resident-vs-
    streamed decision depends on N and the VMEM budget, but the lane-tile
    cap must reflect the LOCAL slice, not the global batch."""
    if system.mode != "batch":
        return system
    return dataclasses.replace(system,
                               batch=shard_lanes(system.batch, n_shards))


def local_tune(system: BandedSystem, n_shards: int, *,
               block_m: int | None = None,
               block_n: int | None = None,
               prefetch: bool = False) -> tuple | None:
    """Per-device ``(block_m, block_n)`` — the single-device 2-D auto-tune
    (``pallas.auto_tune``) run on the LOCAL system view.  ``None`` when no
    kernel configuration fits, or no kernel family serves the mode at all
    (the caller falls back to reference sweeps per shard)."""
    from . import pallas as _pallas
    return _pallas.auto_tune(local_system(system, n_shards),
                             block_m=block_m, block_n=block_n,
                             prefetch=prefetch)


def sharded_solve_stored(bandwidth: int, mode: str, periodic: bool, n: int,
                         stored, rhs: jax.Array, *, mesh: Mesh, batch_axis,
                         n_shards: int, diagonal_names: tuple = (),
                         method: str = "scan", unroll: int = 1,
                         kernels: str = "reference",
                         block_m: int | None = None,
                         block_n: int | None = None,
                         interpret: bool | None = None,
                         fused: bool = False, storage_dtype=None,
                         prefetch: bool = False,
                         transposed: bool = False) -> jax.Array:
    """Pure shard_map dispatch given (static meta, stored pytree, rhs).

    ``kernels="pallas"`` routes every shard through the engine's tuned
    kernel dispatch (``pallas.tuned_solve_stored`` — resident or
    HBM-streamed per the frozen ``(block_m, block_n)``, transposed for the
    adjoint); ``"reference"`` runs the scan sweeps per shard.  Padding the
    M axis to the mesh size uses the kernels' shared ``pad_lanes``:
    per-system MAIN-diagonal copies identity-pad (b = 1) so the dead
    padded lanes factor as identity solves instead of 1/0."""
    from jax.experimental.shard_map import shard_map

    if kernels == "pallas":
        from . import pallas as _pallas

        def local_solve(st, r):
            return _pallas.tuned_solve_stored(
                bandwidth, mode, periodic, st, r, block_m=block_m,
                block_n=block_n, unroll=unroll, interpret=interpret,
                fused=fused, storage_dtype=storage_dtype, prefetch=prefetch,
                transposed=transposed)
    else:
        ref_fn = transpose_solve_stored if transposed else solve_stored

        def local_solve(st, r):
            return ref_fn(bandwidth, mode, periodic, n, st, r,
                          method=method, unroll=unroll)

    squeeze = rhs.ndim == 1
    if squeeze:
        rhs = rhs[:, None]
    m = rhs.shape[1]
    spec = P(None, batch_axis)

    if mode == "batch":
        main = diagonal_names[bandwidth // 2]
        padded = {k: pad_lanes(v, n_shards, identity=(k == main))[0]
                  for k, v in stored.items()}
        fn = shard_map(local_solve, mesh=mesh, in_specs=(spec, spec),
                       out_specs=spec, check_rep=False)
        x = fn(padded, pad_lanes(rhs, n_shards)[0])
    else:
        # replicated: closed over, one copy per device
        fn = shard_map(lambda r: local_solve(stored, r), mesh=mesh,
                       in_specs=(spec,), out_specs=spec, check_rep=False)
        x = fn(pad_lanes(rhs, n_shards)[0])

    x = x[:, :m]
    return x[:, 0] if squeeze else x


# -- the pure-function contract (repro.solver.functional) -------------------

_TRI_NAMES = ("a", "b", "c")
_PENTA_NAMES = ("a", "b", "c", "d", "e")


def _pure_build(system: BandedSystem, *, mesh: Mesh | None = None,
                batch_axis=None, method: str = "scan", unroll: int = 1,
                kernels: str = "auto", block_m: int | None = None,
                block_n: int | None = None, interpret: bool | None = None,
                fused: bool | None = None, storage_dtype=None,
                prefetch: bool = True, **_ignored):
    if kernels not in KERNEL_POLICIES:
        raise ValueError(f"kernels must be one of {KERNEL_POLICIES}, "
                         f"got {kernels!r}")
    sdt = canonical_storage_dtype(storage_dtype)
    mesh, batch_axis, n_shards = resolve_mesh(mesh, batch_axis)
    opts = {"mesh": mesh, "batch_axis": batch_axis, "n_shards": n_shards,
            "method": method, "unroll": unroll}

    tuned = None
    if kernels != "reference":
        tuned = local_tune(system, n_shards, block_m=block_m,
                           block_n=block_n, prefetch=prefetch)
        if tuned is None and kernels == "pallas":
            from . import pallas as _pallas
            _, why = _pallas.supports(local_system(system, n_shards),
                                      block_m=block_m, block_n=block_n)
            raise NotImplementedError(
                f"sharded backend cannot run the engine kernels per shard "
                f"for {system.describe()}: {why}")

    # `shard_build` records which layout the stored factor was BUILT for;
    # `_dispatch` compares it against `kernels` so a post-hoc override
    # cannot route a mismatched pytree into the wrong sweep path.
    if tuned is not None:
        from . import pallas as _pallas
        bm, bn = tuned
        # per-device fused resolution: same traffic-model argmin as the
        # single-device tuner, sized against the LOCAL system view
        fused = _pallas.resolve_fused(local_system(system, n_shards), bm, bn,
                                      fused=fused, prefetch=prefetch,
                                      storage_dtype=sdt)
        opts.update(kernels="pallas", shard_build="pallas", block_m=bm,
                    block_n=bn, interpret=interpret, fused=fused,
                    storage_dtype=None if sdt is None else sdt.name,
                    prefetch=prefetch)
        return _pallas.build_stored(system), opts

    opts.update(kernels="reference", shard_build="reference")
    return build_stored(system, method=method), opts


def _dispatch(meta, stored, rhs, *, transposed: bool):
    # `kernels` is RESOLVED at factorize time: the stored-factor layout and
    # the tuned (block_m, block_n) are bound to the policy that built them
    # (recorded as `shard_build`), so a post-hoc `with_options(fact,
    # kernels=...)` flip would dispatch a mismatched pytree.
    kernels = meta.opt("kernels", "reference")
    if kernels != meta.opt("shard_build", kernels):
        raise ValueError(
            "the sharded backend's `kernels` policy is resolved at factorize "
            "time and cannot be overridden per call; re-factorize with "
            f"kernels={kernels!r} instead")
    names = _TRI_NAMES if meta.bandwidth == 3 else _PENTA_NAMES
    return sharded_solve_stored(
        meta.bandwidth, meta.mode, meta.periodic, meta.n, stored, rhs,
        mesh=meta.opt("mesh"), batch_axis=meta.opt("batch_axis"),
        n_shards=meta.opt("n_shards"), diagonal_names=names,
        method=meta.opt("method", "scan"), unroll=meta.opt("unroll", 1),
        kernels=meta.opt("kernels", "reference"),
        block_m=meta.opt("block_m"), block_n=meta.opt("block_n"),
        interpret=meta.opt("interpret"), fused=meta.opt("fused", False),
        storage_dtype=meta.opt("storage_dtype"),
        prefetch=meta.opt("prefetch", False), transposed=transposed)


def _pure_solve(meta, stored, rhs):
    return _dispatch(meta, stored, rhs, transposed=False)


def _pure_transpose(meta, stored, rhs):
    # The adjoint is sharded too: transposed systems are just as
    # independent over M, so the same shard_map dispatch runs the engine's
    # transposed kernels (or the reference transposed sweeps) per device,
    # reusing the SAME stored factor that served the forward solve.
    return _dispatch(meta, stored, rhs, transposed=True)


register_pure_backend("sharded", build=_pure_build, solve=_pure_solve,
                      transpose_solve=_pure_transpose)


@register_backend("sharded")
class ShardedBackend:
    """shard_map over a device mesh (thin functional shim).

    The LHS is replicated per device (batch mode: sharded with its
    systems) and each shard runs the engine's tuned Pallas kernels when
    ``kernels`` resolves to them (the default ``"auto"`` policy), else the
    reference sweeps.
    """

    def __init__(self, system: BandedSystem, *, mesh: Mesh | None = None,
                 batch_axis: str | tuple | None = None, method: str = "scan",
                 unroll: int = 1, kernels: str = "auto",
                 block_m: int | None = None, block_n: int | None = None,
                 interpret: bool | None = None, fused: bool | None = None,
                 storage_dtype=None, prefetch: bool = True):
        from .functional import factorize
        self.system = system
        self.fact = factorize(system, backend="sharded", mesh=mesh,
                              batch_axis=batch_axis, method=method,
                              unroll=unroll, kernels=kernels,
                              block_m=block_m, block_n=block_n,
                              interpret=interpret, fused=fused,
                              storage_dtype=storage_dtype, prefetch=prefetch)
        self.stored = self.fact.stored
        self.mesh = self.fact.meta.opt("mesh")
        self.batch_axis = self.fact.meta.opt("batch_axis")
        self.n_shards = self.fact.meta.opt("n_shards")
        self.kernels = self.fact.meta.opt("kernels")
        self.block_m = self.fact.meta.opt("block_m")
        self.block_n = self.fact.meta.opt("block_n")

    def solve(self, rhs: jax.Array, *, method: str | None = None,
              unroll: int | None = None) -> jax.Array:
        from .autodiff import solve as _solve
        from .functional import with_options
        fact = self.fact
        if method is not None or unroll is not None:
            fact = with_options(fact, method=method, unroll=unroll)
        return _solve(fact, rhs)
