"""``sharded`` backend: the paper's single-LHS idea at cluster scale.

One LHS copy per DEVICE (replicated — the paper's storage saving applied
per device), the M system axis sharded across a mesh, zero collectives in
the solve: systems are independent, so each device runs the reference
sweeps on its local slice of the interleaved batch.

For ``mode="batch"`` the per-system LHS copies are sharded *with* their
systems (each device only holds the diagonals of its own slice).  The M
axis is padded to a multiple of the mesh size with identity rows
(``main diagonal = 1``) so padded lanes solve trivially and are sliced off.

The pure-function contract: the resolved ``Mesh`` (hashable) rides in the
``Factorization``'s static meta, so a sharded solve crosses ``jit``/``grad``
/``lax.scan`` like any other — the ``shard_map`` is retraced only when the
mesh itself changes.  The adjoint solve runs the replicated reference
transposed sweeps on the same stored factor (transposed systems are just as
independent; distributing them — and composing this mesh layer with the
sweep engine's streamed Pallas kernels per device — is the ROADMAP's
sharded x streamed follow-up, a perf item, not a correctness one).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.common import pad_lanes

from .reference import (build_stored, solve_stored, transpose_solve_stored)
from .registry import register_backend, register_pure_backend
from .system import BandedSystem


def default_mesh(axis_name: str = "batch") -> Mesh:
    """1-D mesh over every visible device."""
    return Mesh(np.array(jax.devices()), (axis_name,))


def resolve_mesh(mesh: Mesh | None, batch_axis):
    """(mesh, batch_axis, n_shards) with the defaulting rules of PR 1."""
    if mesh is None:
        mesh = default_mesh()
        batch_axis = mesh.axis_names[0]
    elif batch_axis is None:
        batch_axis = mesh.axis_names[-1]
    axes = batch_axis if isinstance(batch_axis, tuple) else (batch_axis,)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    return mesh, batch_axis, n_shards


def sharded_solve_stored(bandwidth: int, mode: str, periodic: bool, n: int,
                         stored, rhs: jax.Array, *, mesh: Mesh, batch_axis,
                         n_shards: int, diagonal_names: tuple = (),
                         method: str = "scan", unroll: int = 1) -> jax.Array:
    """Pure shard_map dispatch given (static meta, stored pytree, rhs).

    Padding the M axis to the mesh size uses the kernels' shared
    ``pad_lanes``: per-system MAIN-diagonal copies identity-pad (b = 1) so
    the dead padded lanes factor as identity solves instead of 1/0."""
    from jax.experimental.shard_map import shard_map

    squeeze = rhs.ndim == 1
    if squeeze:
        rhs = rhs[:, None]
    m = rhs.shape[1]
    spec = P(None, batch_axis)

    if mode == "batch":
        main = diagonal_names[bandwidth // 2]
        padded = {k: pad_lanes(v, n_shards, identity=(k == main))[0]
                  for k, v in stored.items()}
        fn = shard_map(
            lambda st, r: solve_stored(bandwidth, mode, periodic, n, st, r,
                                       method=method, unroll=unroll),
            mesh=mesh, in_specs=(spec, spec), out_specs=spec,
            check_rep=False)
        x = fn(padded, pad_lanes(rhs, n_shards)[0])
    else:
        # replicated: closed over, one copy per device
        fn = shard_map(
            lambda r: solve_stored(bandwidth, mode, periodic, n, stored, r,
                                   method=method, unroll=unroll),
            mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False)
        x = fn(pad_lanes(rhs, n_shards)[0])

    x = x[:, :m]
    return x[:, 0] if squeeze else x


# -- the pure-function contract (repro.solver.functional) -------------------

_TRI_NAMES = ("a", "b", "c")
_PENTA_NAMES = ("a", "b", "c", "d", "e")


def _pure_build(system: BandedSystem, *, mesh: Mesh | None = None,
                batch_axis=None, method: str = "scan", unroll: int = 1,
                **_ignored):
    mesh, batch_axis, n_shards = resolve_mesh(mesh, batch_axis)
    return (build_stored(system, method=method),
            {"mesh": mesh, "batch_axis": batch_axis, "n_shards": n_shards,
             "method": method, "unroll": unroll})


def _pure_solve(meta, stored, rhs):
    names = _TRI_NAMES if meta.bandwidth == 3 else _PENTA_NAMES
    return sharded_solve_stored(
        meta.bandwidth, meta.mode, meta.periodic, meta.n, stored, rhs,
        mesh=meta.opt("mesh"), batch_axis=meta.opt("batch_axis"),
        n_shards=meta.opt("n_shards"), diagonal_names=names,
        method=meta.opt("method", "scan"), unroll=meta.opt("unroll", 1))


def _pure_transpose(meta, stored, rhs):
    return transpose_solve_stored(meta.bandwidth, meta.mode, meta.periodic,
                                  meta.n, stored, rhs,
                                  method=meta.opt("method", "scan"),
                                  unroll=meta.opt("unroll", 1))


register_pure_backend("sharded", build=_pure_build, solve=_pure_solve,
                      transpose_solve=_pure_transpose)


@register_backend("sharded")
class ShardedBackend:
    """shard_map-replicated-LHS over a device mesh (thin functional shim)."""

    def __init__(self, system: BandedSystem, *, mesh: Mesh | None = None,
                 batch_axis: str | tuple | None = None, method: str = "scan",
                 unroll: int = 1, block_m=None, block_n=None, interpret=None):
        del block_m, block_n, interpret  # option-set parity with other backends
        from .functional import factorize
        self.system = system
        self.fact = factorize(system, backend="sharded", mesh=mesh,
                              batch_axis=batch_axis, method=method,
                              unroll=unroll)
        self.stored = self.fact.stored
        self.mesh = self.fact.meta.opt("mesh")
        self.batch_axis = self.fact.meta.opt("batch_axis")
        self.n_shards = self.fact.meta.opt("n_shards")

    def solve(self, rhs: jax.Array, *, method: str | None = None,
              unroll: int | None = None) -> jax.Array:
        from .autodiff import solve as _solve
        from .functional import with_options
        fact = self.fact
        if method is not None or unroll is not None:
            fact = with_options(fact, method=method, unroll=unroll)
        return _solve(fact, rhs)
