"""``sharded`` backend: the paper's single-LHS idea at cluster scale.

One LHS copy per DEVICE (replicated — the paper's storage saving applied
per device), the M system axis sharded across a mesh, zero collectives in
the solve: systems are independent, so each device runs the reference
sweeps on its local slice of the interleaved batch.

For ``mode="batch"`` the per-system LHS copies are sharded *with* their
systems (each device only holds the diagonals of its own slice).  The M
axis is padded to a multiple of the mesh size with identity rows
(``main diagonal = 1``) so padded lanes solve trivially and are sliced off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .reference import ReferenceBackend, solve_stored
from .registry import register_backend
from .system import BandedSystem


def default_mesh(axis_name: str = "batch") -> Mesh:
    """1-D mesh over every visible device."""
    return Mesh(np.array(jax.devices()), (axis_name,))


@register_backend("sharded")
class ShardedBackend:
    """shard_map-replicated-LHS over a device mesh."""

    def __init__(self, system: BandedSystem, *, mesh: Mesh | None = None,
                 batch_axis: str | tuple | None = None, method: str = "scan",
                 unroll: int = 1, block_m=None, interpret=None):
        del block_m, interpret  # option-set parity with other backends
        self.system = system
        self._ref = ReferenceBackend(system, method=method, unroll=unroll)
        self.stored = self._ref.stored
        if mesh is None:
            mesh = default_mesh()
            batch_axis = mesh.axis_names[0]
        elif batch_axis is None:
            batch_axis = mesh.axis_names[-1]
        self.mesh = mesh
        self.batch_axis = batch_axis
        axes = batch_axis if isinstance(batch_axis, tuple) else (batch_axis,)
        self.n_shards = int(np.prod([mesh.shape[a] for a in axes]))

    def _pad_batch(self, x: jax.Array, pad: int, main_diag: str | None):
        """Pad the M axis; per-system main-diagonal copies pad with 1 so the
        padded lanes are identity solves (no inf/nan in dead lanes)."""
        if pad == 0:
            return x
        val = 1.0 if main_diag else 0.0
        return jnp.pad(x, [(0, 0), (0, pad)], constant_values=val)

    def solve(self, rhs: jax.Array, *, method: str | None = None,
              unroll: int | None = None) -> jax.Array:
        from jax.experimental.shard_map import shard_map

        s = self.system
        method = method or self._ref.method
        unroll = self._ref.unroll if unroll is None else unroll
        squeeze = rhs.ndim == 1
        if squeeze:
            rhs = rhs[:, None]
        m = rhs.shape[1]
        pad = (-m) % self.n_shards
        spec = P(None, self.batch_axis)

        if s.mode == "batch":
            if s.batch != m:
                raise ValueError(f"batch-mode system built for M={s.batch} "
                                 f"but rhs has M={m}")
            main = s.diagonal_names[s.bandwidth // 2]
            stored = {k: self._pad_batch(v, pad, main_diag=(k == main))
                      for k, v in self.stored.items()}
            fn = shard_map(
                lambda st, r: solve_stored(s.bandwidth, s.mode, s.periodic,
                                           s.n, st, r, method=method,
                                           unroll=unroll),
                mesh=self.mesh, in_specs=(spec, spec), out_specs=spec,
                check_rep=False)
            x = fn(stored, jnp.pad(rhs, [(0, 0), (0, pad)]))
        else:
            stored = self.stored  # replicated: closed over, one copy/device
            fn = shard_map(
                lambda r: solve_stored(s.bandwidth, s.mode, s.periodic,
                                       s.n, stored, r, method=method,
                                       unroll=unroll),
                mesh=self.mesh, in_specs=(spec,), out_specs=spec,
                check_rep=False)
            x = fn(jnp.pad(rhs, [(0, 0), (0, pad)]))

        x = x[:, :m]
        return x[:, 0] if squeeze else x
