"""Transformation-native solver API: pure ``factorize`` / ``solve``.

The paper's storage idea — factor ONE shared LHS, reuse it for an
arbitrarily large interleaved RHS batch — only pays off inside compiled
programs if the factorization can *cross JAX transformation boundaries*.
This module makes the factorization a first-class pytree:

    from repro.solver import BandedSystem, factorize, solve

    fact = factorize(system, backend="auto")     # factor ONCE -> pytree
    x = solve(fact, rhs)                         # pure, jittable
    x = jax.jit(solve)(fact, rhs)                # fact crosses jit
    xs = jax.vmap(solve)(stacked_facts, rhss)    # multi-LHS case
    g = jax.grad(lambda r: solve(fact, r).sum())(rhs)   # differentiable

  * ``Factorization`` is a ``register_dataclass`` pytree: the stored factor
    and the spec diagonals are traced leaves; everything a compiler must
    specialise on (bandwidth, N, mode, boundary condition, backend name,
    resolved backend options) is hashable static aux data (``SolveMeta``).
  * ``solve`` carries a ``jax.custom_vjp`` (``repro.solver.autodiff``)
    whose backward pass solves the TRANSPOSED banded system by reusing the
    same stored factor fields — the paper's ~75 % / ~83 % storage saving
    covers the adjoint too — and returns cotangents for the vector-valued
    diagonals.
  * ``transpose_solve`` exposes the adjoint solve directly (``A^T x = rhs``
    from the forward factorization) for hand-written adjoint codes.

A time loop therefore factors once and scans thousands of steps inside one
compiled program::

    fact = factorize(system)
    def body(field, _):
        return solve(fact, build_rhs(field)), None
    final, _ = jax.lax.scan(body, field0, None, length=10_000)

``Plan`` (``repro.solver.plan``) is now a thin shim over these functions.
Backends plug in through ``registry.register_pure_backend``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from .registry import get_pure_backend
from .system import BandedSystem

# legacy spelling used by the pre-frontend pde layer
ALIASES = {"core": "reference"}


@dataclasses.dataclass(frozen=True)
class SolveMeta:
    """Everything a solve must specialise on — hashable static aux data.

    ``options`` is a sorted tuple of (key, value) pairs of RESOLVED backend
    options (e.g. the auto-tuned ``block_m``, the concrete ``Mesh``): two
    factorizations compare/hash equal exactly when a jitted ``solve`` can be
    retraced-free reused between them.
    """

    bandwidth: int
    n: int
    mode: str
    periodic: bool
    backend: str
    options: tuple = ()

    def opt(self, key: str, default=None):
        for k, v in self.options:
            if k == key:
                return v
        return default

    def with_options(self, **updates) -> "SolveMeta":
        opts = dict(self.options)
        opts.update({k: v for k, v in updates.items() if v is not None})
        return dataclasses.replace(self, options=tuple(sorted(opts.items())))


@dataclasses.dataclass(frozen=True)
class Factorization:
    """A factored LHS as a pytree: leaves trace, meta is static.

    ``stored`` is the backend's factor pytree (the paper's O(k·N) shared
    storage); ``diagonals`` are the spec's (N,) diagonals, carried as leaves
    so ``jax.grad`` can return cotangents for them (the stored factor is
    derived data and receives zero cotangent — see ``repro.solver.autodiff``).
    """

    diagonals: tuple
    stored: Any
    meta: SolveMeta

    # -- conveniences -------------------------------------------------------

    @property
    def backend(self) -> str:
        """Resolved pure-registry backend name (static, from ``meta``)."""
        return self.meta.backend

    def describe(self) -> str:
        """Human-readable one-liner, e.g. ``tridiag/periodic/constant/
        N=512@pallas`` (static meta only — safe under tracing)."""
        kind = "tridiag" if self.meta.bandwidth == 3 else "penta"
        bc = "periodic" if self.meta.periodic else "dirichlet"
        return (f"{kind}/{bc}/{self.meta.mode}/N={self.meta.n}"
                f"@{self.meta.backend}")


jax.tree_util.register_dataclass(
    Factorization,
    data_fields=["diagonals", "stored"],
    meta_fields=["meta"],
)


def select_backend(system: BandedSystem, *, block_m: int | None = None,
                   block_n: int | None = None) -> str:
    """The ``backend="auto"`` policy: pallas when a kernel exists (resident
    OR HBM-streamed split-N — every storage mode streams, batch included),
    else reference (today that means periodic x batch, or a pathologically
    small VMEM budget)."""
    from . import pallas as _pallas

    ok, _why = _pallas.supports(system, block_m=block_m, block_n=block_n)
    return "pallas" if ok else "reference"


def resolve_backend_name(system: BandedSystem, backend: str,
                         block_m: int | None = None,
                         block_n: int | None = None) -> str:
    backend = ALIASES.get(backend, backend)
    if backend == "auto":
        backend = select_backend(system, block_m=block_m, block_n=block_n)
    return backend


def factorize(system: BandedSystem, backend: str = "auto",
              **opts) -> Factorization:
    """Factor ``system`` once into a transformation-crossing pytree.

    ``backend`` is a pure-registry name (``reference`` / ``pallas`` /
    ``sharded``) or ``"auto"`` (pallas when the kernel fits — VMEM-resident
    or HBM-streamed split-N — else reference).  Backend options
    (``method``, ``unroll``, ``block_m``, ``block_n``, ``interpret``,
    ``mesh``, ``batch_axis``, and the sharded backend's per-shard
    ``kernels`` policy) are RESOLVED here — auto-tuning, mesh defaulting,
    kernel-vs-reference fallbacks all happen outside any trace — and
    frozen into the static meta; the returned ``Factorization``'s traced
    leaves are only the stored factor and the spec diagonals, so it
    crosses ``jit``/``vmap``/``grad``/``lax.scan`` freely.
    """
    backend = resolve_backend_name(system, backend, opts.get("block_m"),
                                   opts.get("block_n"))
    pure = get_pure_backend(backend)
    stored, options = pure.build(system, **opts)
    meta = SolveMeta(bandwidth=system.bandwidth, n=system.n,
                     mode=system.mode, periodic=system.periodic,
                     backend=backend, options=tuple(sorted(options.items())))
    return Factorization(diagonals=tuple(system.diagonals), stored=stored,
                         meta=meta)


def _check_batch_width(factorization: Factorization, rhs: jax.Array) -> None:
    """batch mode stores per-system LHS copies: rhs width must match."""
    meta = factorization.meta
    if meta.mode != "batch":
        return
    stored_m = next(iter(factorization.stored.values())).shape[1]
    m = 1 if rhs.ndim == 1 else rhs.shape[1]
    if m != stored_m:
        raise ValueError(f"batch-mode factorization built for M={stored_m} "
                         f"per-system LHS copies but rhs has M={m}")


def solve_impl(factorization: Factorization, rhs: jax.Array) -> jax.Array:
    """The raw (VJP-less) pure solve — dispatch on static meta only.

    Use ``repro.solver.solve`` (the ``custom_vjp``-wrapped spelling from
    ``autodiff``) unless you explicitly want JAX to differentiate through
    the sweep instructions.
    """
    meta = factorization.meta
    _check_batch_width(factorization, rhs)
    return get_pure_backend(meta.backend).solve(meta, factorization.stored,
                                                rhs)


def transpose_solve(factorization: Factorization,
                    rhs: jax.Array) -> jax.Array:
    """Solve ``A^T x = rhs`` reusing the FORWARD factorization.

    This is the backward pass of ``solve`` exposed as a public entry point:
    no transposed refactorisation, no second LHS copy — the same stored
    factor fields serve forward and adjoint (DESIGN.md §5.1).
    """
    meta = factorization.meta
    _check_batch_width(factorization, rhs)
    return get_pure_backend(meta.backend).transpose_solve(
        meta, factorization.stored, rhs)


def with_options(factorization: Factorization, **updates) -> Factorization:
    """A copy of ``factorization`` with per-call option overrides.

    Options are STATIC meta (``None`` values are ignored, not unset): a
    jitted ``solve`` retraces when an option actually changes, exactly as
    it would for a new shape.  The traced leaves are shared, not copied.
    """
    return dataclasses.replace(factorization,
                               meta=factorization.meta.with_options(**updates))
