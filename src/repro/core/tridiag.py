"""Pre-factorised Thomas solver with a single shared LHS (paper §III).

Layout convention (the paper's *interleaved* format): RHS batches are
``(N, M)`` — unknown index ``i`` major, system index ``m`` minor — so each
sweep step touches a contiguous ``(M,)`` slab. The factored LHS is three
``(N,)`` vectors stored **once** (constant mode) or three ``(N, M)`` arrays
(per-system baseline, cuThomasBatch-equivalent); both flow through the same
code path via broadcasting.

Factored form (storage O(3N) — matches the paper's O(3N + MN) total):
    a         : sub-diagonal (a[0] unused, forced to 0)
    inv_denom : 1 / (b_i - a_i * c_hat_{i-1})      (inv_denom[0] = 1/b_0)
    c_hat     : c_i * inv_denom_i                   (c_hat[N-1] unused)

Solve:
    forward   d_hat_i = (d_i - a_i d_hat_{i-1}) * inv_denom_i
    backward  x_i     = d_hat_i - c_hat_i * x_{i+1}

Note: the paper's Eq. (6) prints ``x_i = d̂_i − a_i ĉ_i``; the standard (and
reference-implementation) back-substitution is ``x_i = d̂_i − ĉ_i x_{i+1}``,
which is what we implement.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .recurrence import _align, linear_recurrence


class TridiagFactor(NamedTuple):
    a: jax.Array          # (N,) or (N, M)
    inv_denom: jax.Array  # (N,) or (N, M)
    c_hat: jax.Array      # (N,) or (N, M)


class PeriodicTridiagFactor(NamedTuple):
    factor: TridiagFactor  # factor of the Sherman-Morrison core matrix A'
    z: jax.Array           # A'^{-1} u, shape (N,) or (N, M)
    v_last: jax.Array      # a_0 / gamma (v = e_0 + v_last * e_{N-1})
    inv_denom_sm: jax.Array  # 1 / (1 + v . z)
    zt: jax.Array          # A'^{-T} v — the adjoint's corner aux, same (N,)


def thomas_factor(a: jax.Array, b: jax.Array, c: jax.Array, *,
                  method: str = "scan", unroll: int = 1) -> TridiagFactor:
    """Pre-factorisation (paper Eqs. 1-2). a, b, c: (N,) shared or (N, M)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    c = jnp.asarray(c)
    a = a.at[0].set(0)  # a_0 is outside the matrix

    if method == "scan":
        def step(c_hat_prev, abc):
            a_i, b_i, c_i = abc
            inv = 1.0 / (b_i - a_i * c_hat_prev)
            c_hat_i = c_i * inv
            return c_hat_i, (inv, c_hat_i)

        init = jnp.zeros_like(b[0])
        _, (inv_denom, c_hat) = jax.lax.scan(step, init, (a, b, c), unroll=unroll)
        return TridiagFactor(a=a, inv_denom=inv_denom, c_hat=c_hat)

    if method == "assoc":
        # NOTE: factorisation runs ONCE per operator (the paper's whole point),
        # so the parallel form is provided for completeness only; the running
        # determinant ``den`` grows like prod(denom_i) and can overflow for
        # large N — prefer method="scan" for factorisation, use "assoc" for
        # the per-step *solve* sweeps (whose coefficients |p| < 1 when
        # diagonally dominant).
        # c_hat_i = c_i / (b_i - a_i c_hat_{i-1}) is a Möbius recurrence; track
        # it as a ratio c_hat_i = num_i / den_i with the 2x2 companion form:
        #   (num_i, den_i) = [[0, c_i], [-a_i, b_i]] @ (num_{i-1}, den_{i-1})
        zero = jnp.zeros_like(b)
        A = jnp.stack(
            [jnp.stack([zero, c], axis=1), jnp.stack([-a, b], axis=1)], axis=1
        )  # (N, 2, 2)

        def matmul2(Y, X):  # combine(earlier=X? see below)
            return jnp.einsum("...ij,...jk->...ik", Y, X)

        def combine(fst, snd):
            return matmul2(snd, fst)

        P = jax.lax.associative_scan(combine, A, axis=0)
        num = P[:, 0, 0] * 0 + P[:, 0, 1]  # applied to (num,den)=(0,1)
        den = P[:, 1, 1]
        c_hat = num / den
        # denom_i = b_i - a_i c_hat_{i-1} = den_i / den_{i-1}
        den_prev = jnp.concatenate([jnp.ones_like(den[:1]), den[:-1]], axis=0)
        inv_denom = den_prev / den
        return TridiagFactor(a=a, inv_denom=inv_denom, c_hat=c_hat)

    raise ValueError(f"unknown method {method!r}")


def thomas_solve(f: TridiagFactor, d: jax.Array, *,
                 method: str = "scan", unroll: int = 1) -> jax.Array:
    """Solve A x = d given the factorisation. d: (N,) or (N, M...)."""
    d = jnp.asarray(d)
    a = _align(f.a, d)
    inv_denom = _align(f.inv_denom, d)
    c_hat = _align(f.c_hat, d)

    # forward sweep: d_hat_i = (-a_i inv_i) d_hat_{i-1} + d_i inv_i
    d_hat = linear_recurrence(-a * inv_denom, d * inv_denom,
                              method=method, unroll=unroll)
    # backward sweep: x_i = (-c_hat_i) x_{i+1} + d_hat_i
    x = linear_recurrence(-c_hat, d_hat, reverse=True,
                          method=method, unroll=unroll)
    return x


def thomas_solve_t(f: TridiagFactor, g: jax.Array, *,
                   method: str = "scan", unroll: int = 1) -> jax.Array:
    """Solve the TRANSPOSED system A^T x = g from the SAME factorisation.

    The stored factor is A = L U (L lower bidiagonal with diagonal
    ``1/inv_denom`` and sub-diagonal ``a``; U unit upper bidiagonal with
    super-diagonal ``c_hat``), so A^T = U^T L^T needs no second factor —
    the adjoint of every forward solve reuses the forward's O(3N) storage:

        U^T y = g :  y_i = g_i - c_hat_{i-1} y_{i-1}
        L^T x = y :  x_i = (y_i - a_{i+1} x_{i+1}) * inv_denom_i
    """
    g = jnp.asarray(g)
    a = _align(f.a, g)
    inv_denom = _align(f.inv_denom, g)
    c_hat = _align(f.c_hat, g)

    zero = jnp.zeros_like(c_hat[:1])
    c_hat_prev = jnp.concatenate([zero, c_hat[:-1]], axis=0)   # c_hat_{i-1}
    a_next = jnp.concatenate([a[1:], zero], axis=0)            # a_{i+1}

    y = linear_recurrence(-c_hat_prev, g, method=method, unroll=unroll)
    x = linear_recurrence(-a_next * inv_denom, y * inv_denom,
                          reverse=True, method=method, unroll=unroll)
    return x


def thomas_factor_solve(a, b, c, d, *, method: str = "scan") -> jax.Array:
    """Fused factor+solve (cuThomasBatch semantics: the baseline re-factors on
    every call because its in-place sweeps destroy the LHS copy)."""
    return thomas_solve(thomas_factor(a, b, c, method=method), d, method=method)


# ---------------------------------------------------------------------------
# Periodic boundaries — Sherman-Morrison, paper §III.C (rank-1, paper-faithful)
# ---------------------------------------------------------------------------

def periodic_thomas_factor(a: jax.Array, b: jax.Array, c: jax.Array, *,
                           method: str = "scan") -> PeriodicTridiagFactor:
    """Factor the periodic tridiagonal system (corner entries A[0,N-1] = a_0,
    A[N-1,0] = c_{N-1}) via Sherman-Morrison:  A = A' + u v^T with
        gamma = -b_0,  u = gamma e_0 + c_{N-1} e_{N-1},
        v = e_0 + (a_0/gamma) e_{N-1},
        A'[0,0] = b_0 - gamma = 2 b_0,
        A'[N-1,N-1] = b_{N-1} - c_{N-1} a_0 / gamma.
    The solve of A' z = u happens once here ("need only be performed once at
    the beginning of a given simulation" — paper).
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    c = jnp.asarray(c)
    gamma = -b[0]
    b_mod = b.at[0].add(-gamma).at[-1].add(-c[-1] * a[0] / gamma)
    f = thomas_factor(a, b_mod, c, method=method)

    u = jnp.zeros_like(b).at[0].set(gamma).at[-1].set(c[-1])
    z = thomas_solve(f, u, method=method)
    v_last = a[0] / gamma
    v_dot_z = z[0] + v_last * z[-1]
    # the adjoint's auxiliary solve A'^{-T} v, also once per operator (the
    # backward pass of every solve reuses it, like the forward reuses z)
    v = jnp.zeros_like(b).at[0].set(1.0).at[-1].set(v_last)
    zt = thomas_solve_t(f, v, method=method)
    return PeriodicTridiagFactor(
        factor=f, z=z, v_last=v_last, inv_denom_sm=1.0 / (1.0 + v_dot_z),
        zt=zt,
    )


def periodic_thomas_solve(pf: PeriodicTridiagFactor, d: jax.Array, *,
                          method: str = "scan", unroll: int = 1) -> jax.Array:
    """x = y - (v.y / (1 + v.z)) z  with  y = A'^{-1} d  (paper Eq. 15)."""
    y = thomas_solve(pf.factor, d, method=method, unroll=unroll)
    v_dot_y = y[0] + pf.v_last * y[-1]          # (M,) for batched d
    corr = v_dot_y * pf.inv_denom_sm
    z = _align(pf.z, y) if pf.z.ndim < y.ndim else pf.z
    return y - corr * z


def periodic_corner_correction_t(pf: PeriodicTridiagFactor,
                                 y: jax.Array) -> jax.Array:
    """Transposed Sherman-Morrison corner step on y = A'^{-T} g.

    A = A' + u v^T, so A^T = A'^T + v u^T and Sherman-Morrison gives
        x = y - (u . y) / (1 + u . w) * w,
    with w = A'^{-T} v = ``pf.zt`` (solved once at factor time, exactly
    like the forward's z).  The denominator 1 + u.w = 1 + v.z is the
    stored ``inv_denom_sm`` (scalar transpose); and u is recovered from
    the factor itself (gamma = -b_0 = -1/(2 inv_denom_0), c_{N-1} =
    c_hat_{N-1} / inv_denom_{N-1}) — no second LHS copy anywhere in the
    adjoint.  Shared by the reference transposed solve below and the
    ``pallas`` backend, whose kernels produce the same y — ONE home for
    the factor-convention algebra.
    """
    f = pf.factor
    gamma = -0.5 / f.inv_denom[0]
    c_last = f.c_hat[-1] / f.inv_denom[-1]
    u_dot_y = gamma * y[0] + c_last * y[-1]
    corr = u_dot_y * pf.inv_denom_sm
    zt = _align(pf.zt, y) if pf.zt.ndim < y.ndim else pf.zt
    return y - corr * zt


def periodic_thomas_solve_t(pf: PeriodicTridiagFactor, g: jax.Array, *,
                            method: str = "scan", unroll: int = 1) -> jax.Array:
    """Transposed periodic solve A^T x = g from the SAME stored factor
    (see ``periodic_corner_correction_t`` for the corner algebra)."""
    y = thomas_solve_t(pf.factor, g, method=method, unroll=unroll)
    return periodic_corner_correction_t(pf, y)


def dense_tridiag(a, b, c, periodic: bool = False) -> jax.Array:
    """Materialise the (N, N) matrix — test oracle only."""
    a = jnp.asarray(a); b = jnp.asarray(b); c = jnp.asarray(c)
    n = b.shape[0]
    A = jnp.diag(b) + jnp.diag(a[1:], -1) + jnp.diag(c[:-1], 1)
    if periodic:
        A = A.at[0, n - 1].add(a[0]).at[n - 1, 0].add(c[-1])
    return A
