"""DEPRECATED operator-level API — thin shims over ``repro.solver``.

``TridiagOperator`` / ``PentaOperator`` predate the unified front-end and
are kept for one release with their original call signatures.  New code
should use::

    from repro.solver import BandedSystem, plan
    p = plan(BandedSystem.tridiag(a, b, c, n=n, mode="constant"), backend="auto")
    x = p.solve(rhs)

The three storage modes mirror the paper's comparison matrix:

  * ``constant`` — ONE shared LHS for the whole batch (the paper's
    contribution: cuThomasConstantBatch / cuPentConstantBatch).
    Storage O(k·N + M·N), k = 3 (tridiag) or 5 (penta).
  * ``batch``    — per-system LHS copies, factor fused into every solve
    (cuThomasBatch / cuPentBatch, the prior state of the art).
    Storage O((k+1)·M·N), k+1 = 4 or 6.
  * ``uniform``  — all entries of each diagonal equal (cuPentUniformBatch):
    the eps/a vector degenerates to a scalar. Storage O((k-1)·N + M·N).

``storage_bytes()`` reports the *actual* bytes held by the operator so the
paper's ~75 % / ~83 % reduction claims are asserted by tests rather than
quoted.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_DEPRECATION = ("%s is deprecated; use repro.solver.plan(BandedSystem.%s(...))"
                " — the operators remain for one release as shims.")


def _nbytes(tree: Any) -> int:
    return int(sum(  # speclint: allow-concretize — static shape math
        np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)))


def _solver():
    # lazy: repro.solver imports repro.core, so a module-level import here
    # would be circular.
    from repro import solver
    return solver


@dataclasses.dataclass(frozen=True)
class TridiagOperator:
    """Batched tridiagonal solve with selectable storage mode (deprecated
    shim over ``repro.solver``)."""

    mode: str                  # constant | batch | uniform
    periodic: bool
    n: int
    stored: Any                # factor pytree (constant/uniform) or raw diagonals (batch)

    @classmethod
    def create(cls, a, b, c, *, n: int | None = None, mode: str = "constant",
               periodic: bool = False, batch: int | None = None,
               dtype=jnp.float32, method: str = "scan") -> "TridiagOperator":
        warnings.warn(_DEPRECATION % ("TridiagOperator", "tridiag"),
                      DeprecationWarning, stacklevel=2)
        solver = _solver()
        system = solver.BandedSystem.tridiag(
            a, b, c, n=n, mode=mode, periodic=periodic, batch=batch,
            dtype=dtype)
        p = solver.plan(system, backend="reference", method=method)
        return cls(mode=mode, periodic=periodic, n=system.n,
                   stored=p.impl.stored)

    def _factor_for_solve(self):
        from repro.solver import reference as _ref
        if self.mode == "uniform":
            return _ref.expand_uniform(3, self.periodic, self.n, self.stored)
        return self.stored

    def solve(self, d: Array, *, method: str = "scan", unroll: int = 1) -> Array:
        """d: (N,) or (N, M) interleaved RHS batch."""
        from repro.solver import reference as _ref
        return _ref.solve_stored(3, self.mode, self.periodic, self.n,
                                 self.stored, d, method=method, unroll=unroll)

    def storage_bytes(self, *, rhs_batch: int | None = None, itemsize: int = 4) -> dict:
        lhs = _nbytes(self.stored)
        out = {"lhs_bytes": lhs, "mode": self.mode, "n": self.n}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out


@dataclasses.dataclass(frozen=True)
class PentaOperator:
    """Batched pentadiagonal solve (deprecated shim over ``repro.solver``)."""

    mode: str
    periodic: bool
    n: int
    stored: Any

    @classmethod
    def create(cls, a, b, c, d, e, *, n: int | None = None, mode: str = "constant",
               periodic: bool = False, batch: int | None = None,
               dtype=jnp.float32) -> "PentaOperator":
        warnings.warn(_DEPRECATION % ("PentaOperator", "penta"),
                      DeprecationWarning, stacklevel=2)
        solver = _solver()
        system = solver.BandedSystem.penta(
            a, b, c, d, e, n=n, mode=mode, periodic=periodic, batch=batch,
            dtype=dtype)
        p = solver.plan(system, backend="reference")
        return cls(mode=mode, periodic=periodic, n=system.n,
                   stored=p.impl.stored)

    def _factor_for_solve(self):
        from repro.solver import reference as _ref
        if self.mode == "uniform":
            return _ref.expand_uniform(5, self.periodic, self.n, self.stored)
        return self.stored

    def solve(self, rhs: Array, *, method: str = "scan", unroll: int = 1) -> Array:
        from repro.solver import reference as _ref
        return _ref.solve_stored(5, self.mode, self.periodic, self.n,
                                 self.stored, rhs, method=method,
                                 unroll=unroll)

    def storage_bytes(self, *, rhs_batch: int | None = None, itemsize: int = 4) -> dict:
        lhs = _nbytes(self.stored)
        out = {"lhs_bytes": lhs, "mode": self.mode, "n": self.n}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out
