"""Operator-level API over the tridiagonal / pentadiagonal solvers.

Three storage modes, mirroring the paper's comparison matrix:

  * ``constant`` — ONE shared LHS for the whole batch (the paper's
    contribution: cuThomasConstantBatch / cuPentConstantBatch).
    Storage O(k·N + M·N), k = 3 (tridiag) or 5 (penta).
  * ``batch``    — per-system LHS copies, factor fused into every solve and
    the factored arrays conceptually overwritten (cuThomasBatch / cuPentBatch,
    the prior state of the art the paper benchmarks against).
    Storage O((k+1)·M·N), k+1 = 4 or 6.
  * ``uniform``  — all entries of each diagonal equal (cuPentUniformBatch):
    the eps/a vector degenerates to a scalar. Storage O((k-1)·N + M·N).

``storage_bytes()`` reports the *actual* bytes held by the operator so the
paper's ~75 % / ~83 % reduction claims are asserted by tests rather than
quoted.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import penta as _penta
from . import tridiag as _tridiag

Array = jax.Array


def _as_vec(x, n: int, dtype) -> jax.Array:
    x = jnp.asarray(x, dtype=dtype)
    if x.ndim == 0:
        return jnp.full((n,), x, dtype=dtype)
    return x


def _nbytes(tree: Any) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True)
class TridiagOperator:
    """Batched tridiagonal solve with selectable storage mode."""

    mode: str                  # constant | batch | uniform
    periodic: bool
    n: int
    stored: Any                # factor pytree (constant/uniform) or raw diagonals (batch)

    @classmethod
    def create(cls, a, b, c, *, n: int | None = None, mode: str = "constant",
               periodic: bool = False, batch: int | None = None,
               dtype=jnp.float32, method: str = "scan") -> "TridiagOperator":
        if n is None:
            n = jnp.asarray(b).shape[0]
        a = _as_vec(a, n, dtype); b = _as_vec(b, n, dtype); c = _as_vec(c, n, dtype)

        if mode == "batch":
            if batch is None:
                raise ValueError("batch mode requires batch=M (per-system LHS copies)")
            # the baseline materialises one LHS copy per system (interleaved):
            tile = lambda v: jnp.broadcast_to(v[:, None], (n, batch)) + jnp.zeros((n, batch), dtype)
            stored = dict(a=tile(a), b=tile(b), c=tile(c))
            return cls(mode=mode, periodic=periodic, n=n, stored=stored)

        if mode in ("constant", "uniform"):
            if periodic:
                f = _tridiag.periodic_thomas_factor(a, b, c, method=method)
            else:
                f = _tridiag.thomas_factor(a, b, c, method=method)
            if mode == "uniform":
                # all-equal diagonals: the `a` vector inside the factor is a
                # scalar broadcast — store it as 0-d (O(2N) factor storage).
                if periodic:
                    inner = f.factor._replace(a=f.factor.a[1])
                    f = f._replace(factor=inner)
                else:
                    f = f._replace(a=f.a[1])
            return cls(mode=mode, periodic=periodic, n=n, stored=f)

        raise ValueError(f"unknown mode {mode!r}")

    def _factor_for_solve(self):
        f = self.stored
        if self.mode == "uniform":
            if self.periodic:
                inner = f.factor
                a = jnp.full((self.n,), inner.a, inner.inv_denom.dtype).at[0].set(0)
                return f._replace(factor=inner._replace(a=a))
            a = jnp.full((self.n,), f.a, f.inv_denom.dtype).at[0].set(0)
            return f._replace(a=a)
        return f

    def solve(self, d: Array, *, method: str = "scan", unroll: int = 1) -> Array:
        """d: (N,) or (N, M) interleaved RHS batch."""
        if self.mode == "batch":
            s = self.stored
            if self.periodic:
                def one(a, b, c, d1):
                    pf = _tridiag.periodic_thomas_factor(a, b, c, method=method)
                    return _tridiag.periodic_thomas_solve(pf, d1, method=method)
                return jax.vmap(one, in_axes=1, out_axes=1)(s["a"], s["b"], s["c"], d)
            # cuThomasBatch semantics: factor fused into the solve, every call.
            return _tridiag.thomas_factor_solve(s["a"], s["b"], s["c"], d, method=method)

        f = self._factor_for_solve()
        if self.periodic:
            return _tridiag.periodic_thomas_solve(f, d, method=method, unroll=unroll)
        return _tridiag.thomas_solve(f, d, method=method, unroll=unroll)

    def storage_bytes(self, *, rhs_batch: int | None = None, itemsize: int = 4) -> dict:
        lhs = _nbytes(self.stored)
        out = {"lhs_bytes": lhs, "mode": self.mode, "n": self.n}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out


@dataclasses.dataclass(frozen=True)
class PentaOperator:
    mode: str
    periodic: bool
    n: int
    stored: Any

    @classmethod
    def create(cls, a, b, c, d, e, *, n: int | None = None, mode: str = "constant",
               periodic: bool = False, batch: int | None = None,
               dtype=jnp.float32) -> "PentaOperator":
        if n is None:
            n = jnp.asarray(c).shape[0]
        a = _as_vec(a, n, dtype); b = _as_vec(b, n, dtype); c = _as_vec(c, n, dtype)
        d = _as_vec(d, n, dtype); e = _as_vec(e, n, dtype)

        if mode == "batch":
            if batch is None:
                raise ValueError("batch mode requires batch=M")
            tile = lambda v: jnp.broadcast_to(v[:, None], (n, batch)) + jnp.zeros((n, batch), dtype)
            stored = dict(a=tile(a), b=tile(b), c=tile(c), d=tile(d), e=tile(e))
            return cls(mode=mode, periodic=periodic, n=n, stored=stored)

        if mode in ("constant", "uniform"):
            if periodic:
                f = _penta.periodic_penta_factor(a, b, c, d, e)
            else:
                f = _penta.penta_factor(a, b, c, d, e)
            if mode == "uniform":
                # cuPentUniformBatch: drop the eps (= a) vector -> scalar.
                if periodic:
                    f = f._replace(factor=f.factor._replace(eps=f.factor.eps[2]))
                else:
                    f = f._replace(eps=f.eps[2])
            return cls(mode=mode, periodic=periodic, n=n, stored=f)

        raise ValueError(f"unknown mode {mode!r}")

    def _factor_for_solve(self):
        f = self.stored
        if self.mode == "uniform":
            def fix(inner):
                eps = jnp.full((self.n,), inner.eps, inner.beta.dtype)
                eps = eps.at[jnp.array([0, 1])].set(0)
                return inner._replace(eps=eps)
            if self.periodic:
                return f._replace(factor=fix(f.factor))
            return fix(f)
        return f

    def solve(self, rhs: Array, *, method: str = "scan", unroll: int = 1) -> Array:
        if self.mode == "batch":
            s = self.stored
            if self.periodic:
                def one(a, b, c, d, e, r):
                    pf = _penta.periodic_penta_factor(a, b, c, d, e)
                    return _penta.periodic_penta_solve(pf, r, method=method)
                return jax.vmap(one, in_axes=1, out_axes=1)(
                    s["a"], s["b"], s["c"], s["d"], s["e"], rhs)
            return _penta.penta_factor_solve(
                s["a"], s["b"], s["c"], s["d"], s["e"], rhs, method=method)

        f = self._factor_for_solve()
        if self.periodic:
            return _penta.periodic_penta_solve(f, rhs, method=method, unroll=unroll)
        return _penta.penta_solve(f, rhs, method=method, unroll=unroll)

    def storage_bytes(self, *, rhs_batch: int | None = None, itemsize: int = 4) -> dict:
        lhs = _nbytes(self.stored)
        out = {"lhs_bytes": lhs, "mode": self.mode, "n": self.n}
        if rhs_batch is not None:
            out["rhs_bytes"] = self.n * rhs_batch * itemsize
            out["total_bytes"] = lhs + out["rhs_bytes"]
        return out
