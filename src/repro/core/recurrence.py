"""First-order / second-order linear recurrence engine.

This is the computational primitive shared by:
  * the Thomas tridiagonal sweeps   (h_i = p_i h_{i-1} + q_i),
  * the pentadiagonal LR sweeps     (h_i = s_i h_{i-1} + t_i h_{i-2} + u_i),
  * the SSM layers (Mamba-2 SSD inter-chunk state scan, RG-LRU) in
    ``repro.models`` — the paper's "single shared LHS, many interleaved RHS"
    pattern shows up here as shared (N,)-shaped coefficients broadcast across a
    batch of (N, M)-shaped operands.

Two execution strategies:
  * ``method="scan"``  — sequential ``lax.scan`` (work-optimal, O(N) depth).
  * ``method="assoc"`` — ``lax.associative_scan`` (O(log N) depth, ~2x work),
    the TPU analogue of parallel cyclic reduction for long N.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _align(coef: jax.Array, ref: jax.Array) -> jax.Array:
    """Right-pad ``coef`` with singleton dims so it broadcasts against ``ref``.

    ``coef`` has shape (N,) (shared coefficients — the paper's constant-LHS
    case) or ``ref.shape`` (per-system coefficients — the baseline case).
    """
    coef = jnp.asarray(coef)
    if coef.ndim == ref.ndim:
        return coef
    if coef.ndim != 1:
        raise ValueError(f"coefficient rank {coef.ndim} vs operand rank {ref.ndim}")
    return coef.reshape(coef.shape + (1,) * (ref.ndim - 1))


def linear_recurrence(
    p: jax.Array,
    q: jax.Array,
    h0: jax.Array | None = None,
    *,
    reverse: bool = False,
    method: str = "scan",
    unroll: int = 1,
) -> jax.Array:
    """Solve h_i = p_i * h_{i-1} + q_i for i = 0..N-1 (h_{-1} = h0, default 0).

    p: (N,) or (N, ...) — multiplicative coefficients (shared or per-system).
    q: (N, ...)         — additive operands (e.g. interleaved RHS batch (N, M)).
    reverse: run the recurrence from i = N-1 down to 0 (h_i depends on h_{i+1}).
    Returns h with q's shape.
    """
    q = jnp.asarray(q)
    p = _align(p, q)

    if method == "scan":
        def step(h, pq):
            p_i, q_i = pq
            h_new = p_i * h + q_i
            return h_new, h_new

        init = jnp.zeros_like(q[0]) if h0 is None else jnp.broadcast_to(h0, q[0].shape).astype(q.dtype)
        _, h = jax.lax.scan(step, init, (p, q), reverse=reverse, unroll=unroll)
        return h

    if method == "assoc":
        def combine(fst, snd):
            # fst happened earlier in scan order; composition:
            # h -> p2*(p1*h + q1) + q2 = (p1*p2)*h + (p2*q1 + q2)
            p1, q1 = fst
            p2, q2 = snd
            return p1 * p2, p2 * q1 + q2

        pp, qq = jax.lax.associative_scan(combine, (p, q), reverse=reverse, axis=0)
        if h0 is not None:
            return pp * jnp.broadcast_to(h0, q[0].shape).astype(q.dtype) + qq
        return qq

    raise ValueError(f"unknown method {method!r}")


def linear_recurrence2(
    s: jax.Array,
    t: jax.Array,
    u: jax.Array,
    *,
    reverse: bool = False,
    method: str = "scan",
    unroll: int = 1,
) -> jax.Array:
    """Solve h_i = s_i h_{i-1} + t_i h_{i-2} + u_i  (h_{-1} = h_{-2} = 0).

    With ``reverse=True`` solves h_i = s_i h_{i+1} + t_i h_{i+2} + u_i
    (h_N = h_{N+1} = 0) — the pentadiagonal back-substitution shape.

    s, t: (N,) or (N, ...);  u: (N, ...).
    """
    u = jnp.asarray(u)
    s = _align(s, u)
    t = _align(t, u)

    if method == "scan":
        def step(carry, stu):
            h1, h2 = carry  # h_{i-1}, h_{i-2}
            s_i, t_i, u_i = stu
            h_new = s_i * h1 + t_i * h2 + u_i
            return (h_new, h1), h_new

        init = (jnp.zeros_like(u[0]), jnp.zeros_like(u[0]))
        _, h = jax.lax.scan(step, init, (s, t, u), reverse=reverse, unroll=unroll)
        return h

    if method == "assoc":
        # 2x2 companion-matrix associative scan:
        #   H_i = [[s_i, t_i], [1, 0]] H_{i-1} + [u_i, 0],  H = (h_i, h_{i-1}).
        one = jnp.ones_like(s)
        zero = jnp.zeros_like(s)
        # A: (N, 2, 2, ...), b: (N, 2, ...) — move the 2x2 in axes 1,2.
        A = jnp.stack(
            [jnp.stack([s, t], axis=1), jnp.stack([one, zero], axis=1)], axis=1
        )  # (N, 2, 2, ...)
        b = jnp.stack([u, jnp.zeros_like(u)], axis=1)  # (N, 2, ...)

        def matmul2(X, Y):
            # X, Y: (k, 2, 2, ...) — contract the inner 2-dims explicitly.
            return jnp.stack(
                [
                    jnp.stack(
                        [
                            X[:, 0, 0] * Y[:, 0, 0] + X[:, 0, 1] * Y[:, 1, 0],
                            X[:, 0, 0] * Y[:, 0, 1] + X[:, 0, 1] * Y[:, 1, 1],
                        ],
                        axis=1,
                    ),
                    jnp.stack(
                        [
                            X[:, 1, 0] * Y[:, 0, 0] + X[:, 1, 1] * Y[:, 1, 0],
                            X[:, 1, 0] * Y[:, 0, 1] + X[:, 1, 1] * Y[:, 1, 1],
                        ],
                        axis=1,
                    ),
                ],
                axis=1,
            )

        def matvec2(X, v):
            # X: (k, 2, 2, ...), v: (k, 2, ...)
            return jnp.stack(
                [
                    X[:, 0, 0] * v[:, 0] + X[:, 0, 1] * v[:, 1],
                    X[:, 1, 0] * v[:, 0] + X[:, 1, 1] * v[:, 1],
                ],
                axis=1,
            )

        def combine(fst, snd):
            A1, b1 = fst
            A2, b2 = snd
            return matmul2(A2, A1), matvec2(A2, b1) + b2

        _, bb = jax.lax.associative_scan(combine, (A, b), reverse=reverse, axis=0)
        return bb[:, 0]

    raise ValueError(f"unknown method {method!r}")
