"""First-order / second-order linear recurrence engine.

This is the computational primitive shared by:
  * the Thomas tridiagonal sweeps   (h_i = p_i h_{i-1} + q_i),
  * the pentadiagonal LR sweeps     (h_i = s_i h_{i-1} + t_i h_{i-2} + u_i),
  * the SSM layers (Mamba-2 SSD inter-chunk state scan, RG-LRU) in
    ``repro.models`` — the paper's "single shared LHS, many interleaved RHS"
    pattern shows up here as shared (N,)-shaped coefficients broadcast across a
    batch of (N, M)-shaped operands.

Execution strategies (the ``method`` dispatch):
  * ``"scan"``   — sequential ``lax.scan`` (work-optimal, O(N) depth).
  * ``"assoc"``  — ``lax.associative_scan`` (O(log N) depth, ~2x work),
    the TPU analogue of parallel cyclic reduction for long N.
  * ``"pallas"`` — the engine-generated gated-recurrence Pallas kernels
    (``repro.kernels.engine.RecurrenceSpec``): the recurrence rides the
    same sweep machine as the banded solvers, with VMEM-aware lane/chunk
    tuning (``_auto_blocks``, reusing ``kernels.common``'s budget model)
    and a ``custom_vjp`` running the ADJOINT recurrence on the same
    kernels (the reverse sweep with gates shifted by one lag — exactly
    the transposed-solver trick of DESIGN.md §5.1 applied to gates).
  * ``"auto"``   — ``"pallas"`` for floating-point operands, ``"scan"``
    otherwise.  This is the policy the sequence models use.

All methods share one dtype/broadcast contract: coefficients are (N,),
or broadcastable against the operand (singleton dims allowed), the
computation runs in ``jnp.result_type`` of the inputs (so bf16 operands
with fp32 gates run fp32 — the models' fp32-carry convention), ``h0``
seeds the incoming carry state, and ``reverse=True`` runs from i = N-1
down to 0.  The parity across methods is pinned by
``tests/test_recurrence.py``'s (method x reverse x h0 x dtype) sweep.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

METHODS = ("scan", "assoc", "pallas", "auto")


def _align(coef: jax.Array, ref: jax.Array) -> jax.Array:
    """Right-pad ``coef`` with singleton dims so it broadcasts against ``ref``.

    ``coef`` has shape (N,) (shared coefficients — the paper's constant-LHS
    case) or broadcasts against ``ref.shape`` (per-system coefficients,
    singleton dims allowed — the SSD inter-chunk decay is (N, B, H, 1, 1)).
    """
    coef = jnp.asarray(coef)
    if coef.ndim == ref.ndim:
        return coef
    if coef.ndim != 1:
        raise ValueError(f"coefficient rank {coef.ndim} vs operand rank {ref.ndim}")
    return coef.reshape(coef.shape + (1,) * (ref.ndim - 1))


def _resolve(method: str, dtype) -> str:
    """The auto policy: Pallas serves every floating recurrence (interpret
    mode off-TPU); integer/bool recurrences stay on the XLA scan."""
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; valid: {METHODS}")
    if method != "auto":
        return method
    return "pallas" if jnp.issubdtype(dtype, jnp.floating) else "scan"


def _shift_up(v: jax.Array, k: int) -> jax.Array:
    """Row i reads v at i+k (zeros shift in at the bottom)."""
    return jnp.concatenate([v[k:], jnp.zeros_like(v[:k])], axis=0)


def _shift_down(v: jax.Array, k: int) -> jax.Array:
    """Row i reads v at i-k (zeros shift in at the top)."""
    return jnp.concatenate([jnp.zeros_like(v[:k]), v[:-k]], axis=0)


# ---------------------------------------------------------------------------
# Pallas dispatch: block tuning + flattening onto the (N, M) kernel layout
# ---------------------------------------------------------------------------

_BLOCK_M_CANDIDATES = (1024, 512, 256, 128)
_BLOCK_N_CANDIDATES = (2048, 1024, 512, 256)


def _auto_blocks(order: int, n: int, m: int, itemsize: int) -> tuple:
    """(block_m, block_n) for an order-``order`` recurrence over an (n, m)
    batch: the largest resident lane tile whose working set fits the VMEM
    budget (``block_n=None``), else the streamed split-N kernel at the
    largest chunk that fits — the same budget model as the banded solvers
    (``kernels.common``), with the counts derived from the registered
    ``RecurrenceSpec``."""
    from repro.kernels import common as kcommon
    from repro.kernels.engine import find_recurrence_spec
    n_rhs, n_lhs, n_carry = find_recurrence_spec(order).vmem_counts()
    cap = max(kcommon.LANE, m)
    for bm in _BLOCK_M_CANDIDATES:
        if bm > max(cap, _BLOCK_M_CANDIDATES[-1]):
            continue
        ws = kcommon.vmem_working_set(n, bm, n_rhs, n_lhs, itemsize=itemsize)
        if ws <= kcommon.VMEM_BUDGET_BYTES:
            return bm, None
    bm = _BLOCK_M_CANDIDATES[-1]
    for bn in _BLOCK_N_CANDIDATES:
        ws = kcommon.streamed_vmem_working_set(bn, bm, n_rhs, n_lhs, n_carry,
                                               itemsize=itemsize)
        if ws <= kcommon.VMEM_BUDGET_BYTES:
            return bm, bn
    return bm, _BLOCK_N_CANDIDATES[-1]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _recur1_pallas(reverse, block_m, block_n, interpret, p, q, h0):
    """Order-1 Pallas recurrence on flattened (N, M) operands; ``h0`` is a
    concrete (M,) seed (zeros when the caller passed None)."""
    from repro.kernels import ops as kops
    return kops.recurrence(p, q, h0=h0, reverse=reverse, block_m=block_m,
                           block_n=block_n, interpret=interpret)


def _recur1_fwd(reverse, block_m, block_n, interpret, p, q, h0):
    h = _recur1_pallas(reverse, block_m, block_n, interpret, p, q, h0)
    return h, (p, h, h0)


def _recur1_bwd(reverse, block_m, block_n, interpret, res, g):
    """Adjoint of h_i = p_i h_{i-1} + q_i: the SAME recurrence walked the
    other way with the gate shifted one step (lambda_i = g_i +
    p_{i+1} lambda_{i+1}), run on the same Pallas kernels; then
    dp_i = lambda_i h_{i-1}, dq = lambda, dh0 = lambda_0 p_0."""
    from repro.kernels import ops as kops
    p, h, h0 = res
    if reverse:
        p_adj, lam_rev = _shift_down(p, 1), False
        h_prev = jnp.concatenate([h[1:], h0[None]], axis=0)
    else:
        p_adj, lam_rev = _shift_up(p, 1), True
        h_prev = jnp.concatenate([h0[None], h[:-1]], axis=0)
    lam = kops.recurrence(p_adj, g, reverse=lam_rev, block_m=block_m,
                          block_n=block_n, interpret=interpret)
    edge = -1 if reverse else 0
    return lam * h_prev, lam, lam[edge] * p[edge]


_recur1_pallas.defvjp(_recur1_fwd, _recur1_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _recur2_pallas(reverse, block_m, block_n, interpret, s, t, u, h1, h2):
    """Order-2 Pallas recurrence on flattened (N, M) operands; ``(h1, h2)``
    are the concrete (M,) seeds (h_{-1}, h_{-2}), zeros for None."""
    from repro.kernels import ops as kops
    return kops.recurrence(s, t, u, h0=(h1, h2), reverse=reverse,
                           block_m=block_m, block_n=block_n,
                           interpret=interpret)


def _recur2_fwd(reverse, block_m, block_n, interpret, s, t, u, h1, h2):
    h = _recur2_pallas(reverse, block_m, block_n, interpret, s, t, u, h1, h2)
    return h, (s, t, h, h1, h2)


def _recur2_bwd(reverse, block_m, block_n, interpret, res, g):
    """Adjoint of the order-2 recurrence: lambda_i = g_i +
    s_{i+1} lambda_{i+1} + t_{i+2} lambda_{i+2} — the reverse recurrence
    with each gate shifted by its own lag."""
    from repro.kernels import ops as kops
    s, t, h, h1, h2 = res
    n = h.shape[0]
    if reverse:
        s_adj, t_adj, lam_rev = _shift_down(s, 1), _shift_down(t, 2), False
        hp1 = jnp.concatenate([h[1:], h1[None]], axis=0)
        hp2 = jnp.concatenate([h[2:], h1[None], h2[None]], axis=0)[:n]
        e0, e1 = n - 1, n - 2
    else:
        s_adj, t_adj, lam_rev = _shift_up(s, 1), _shift_up(t, 2), True
        hp1 = jnp.concatenate([h1[None], h[:-1]], axis=0)
        hp2 = jnp.concatenate([h2[None], h1[None], h[:-2]], axis=0)[:n]
        e0, e1 = 0, 1
    lam = kops.recurrence(s_adj, t_adj, g, reverse=lam_rev, block_m=block_m,
                          block_n=block_n, interpret=interpret)
    dh1 = lam[e0] * s[e0]
    if n > 1:
        dh1 = dh1 + lam[e1] * t[e1]
    dh2 = lam[e0] * t[e0]
    return lam * hp1, lam * hp2, lam, dh1, dh2


_recur2_pallas.defvjp(_recur2_fwd, _recur2_bwd)


def _pallas_dispatch(gates: tuple, q: jax.Array, h0: tuple | None, *,
                     reverse: bool, block_m: int | None,
                     block_n: int | None, interpret: bool | None
                     ) -> jax.Array:
    """Flatten (N, ...) operands onto the kernels' (N, M) layout, tune the
    blocks against the VMEM budget, and run the differentiable Pallas
    recurrence.  Gates broadcast to the operand shape on the host (a
    shared (N,) gate becomes a full gate operand — the recurrence layout
    has no shared-LHS stream)."""
    order = len(gates)
    n = q.shape[0]
    shape = q.shape
    m = math.prod(shape[1:])
    gates = tuple(jnp.broadcast_to(g, shape).reshape(n, m) for g in gates)
    qf = q.reshape(n, m)
    if h0 is None:
        seeds = tuple(jnp.zeros((m,), q.dtype) for _ in range(order))
    else:
        seeds = tuple(jnp.broadcast_to(h.astype(q.dtype),
                                       shape[1:]).reshape(m) for h in h0)
    if block_m is None:
        block_m, auto_bn = _auto_blocks(order, n, m, jnp.dtype(q.dtype).itemsize)
        if block_n is None:
            block_n = auto_bn
    if order == 1:
        h = _recur1_pallas(reverse, block_m, block_n, interpret,
                           gates[0], qf, seeds[0])
    else:
        h = _recur2_pallas(reverse, block_m, block_n, interpret,
                           gates[0], gates[1], qf, seeds[0], seeds[1])
    return h.reshape(shape)


# ---------------------------------------------------------------------------
# Public front end
# ---------------------------------------------------------------------------

def linear_recurrence(
    p: jax.Array,
    q: jax.Array,
    h0: jax.Array | None = None,
    *,
    reverse: bool = False,
    method: str = "scan",
    unroll: int = 1,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Solve h_i = p_i * h_{i-1} + q_i for i = 0..N-1 (h_{-1} = h0, default 0).

    p: (N,) or broadcastable against q — multiplicative gates.
    q: (N, ...)  — additive operands (e.g. interleaved RHS batch (N, M)).
    reverse: run the recurrence from i = N-1 down to 0 (h_i depends on h_{i+1}).
    method: "scan" | "assoc" | "pallas" | "auto" (see module docstring);
    every method computes in ``jnp.result_type(p, q)`` and honours
    (h0 x reverse) identically.  ``block_m``/``block_n``/``interpret``
    tune the pallas path only (None = VMEM-aware auto).
    Returns h with q's shape in the promoted dtype.
    """
    q = jnp.asarray(q)
    p = _align(p, q)
    dtype = jnp.result_type(p.dtype, q.dtype)
    p, q = p.astype(dtype), q.astype(dtype)
    method = _resolve(method, dtype)

    if method == "pallas":
        return _pallas_dispatch(
            (p,), q, None if h0 is None else (jnp.asarray(h0),),
            reverse=reverse, block_m=block_m, block_n=block_n,
            interpret=interpret)

    if h0 is not None:
        h0 = jnp.broadcast_to(jnp.asarray(h0), q.shape[1:]).astype(dtype)

    if method == "scan":
        def step(h, pq):
            p_i, q_i = pq
            h_new = p_i * h + q_i
            return h_new, h_new

        init = jnp.zeros(q.shape[1:], dtype) if h0 is None else h0
        _, h = jax.lax.scan(step, init, (p, q), reverse=reverse, unroll=unroll)
        return h

    # assoc
    def combine(fst, snd):
        # fst happened earlier in scan order; composition:
        # h -> p2*(p1*h + q1) + q2 = (p1*p2)*h + (p2*q1 + q2)
        p1, q1 = fst
        p2, q2 = snd
        return p1 * p2, p2 * q1 + q2

    p_full = jnp.broadcast_to(p, q.shape)
    pp, qq = jax.lax.associative_scan(combine, (p_full, q), reverse=reverse,
                                      axis=0)
    if h0 is not None:
        return pp * h0 + qq
    return qq


def linear_recurrence2(
    s: jax.Array,
    t: jax.Array,
    u: jax.Array,
    h0: tuple | None = None,
    *,
    reverse: bool = False,
    method: str = "scan",
    unroll: int = 1,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Solve h_i = s_i h_{i-1} + t_i h_{i-2} + u_i  (seeds default to 0).

    With ``reverse=True`` solves h_i = s_i h_{i+1} + t_i h_{i+2} + u_i
    (h_N = h_{N+1} = 0) — the pentadiagonal back-substitution shape.

    s, t: (N,) or broadcastable against u;  u: (N, ...).
    h0: optional ``(h_{-1}, h_{-2})`` seed pair (``(h_N, h_{N+1})`` when
    reversed), each broadcastable over the batch dims.  Methods and
    dtype/broadcast rules match ``linear_recurrence``.
    """
    u = jnp.asarray(u)
    s = _align(s, u)
    t = _align(t, u)
    dtype = jnp.result_type(s.dtype, t.dtype, u.dtype)
    s, t, u = s.astype(dtype), t.astype(dtype), u.astype(dtype)
    method = _resolve(method, dtype)

    if h0 is not None:
        if len(h0) != 2:
            raise ValueError("h0 must be a (h_{-1}, h_{-2}) pair")
        h0 = tuple(jnp.broadcast_to(jnp.asarray(h), u.shape[1:]).astype(dtype)
                   for h in h0)

    if method == "pallas":
        return _pallas_dispatch((s, t), u, h0, reverse=reverse,
                                block_m=block_m, block_n=block_n,
                                interpret=interpret)

    if method == "scan":
        def step(carry, stu):
            h1, h2 = carry  # h_{i-1}, h_{i-2}
            s_i, t_i, u_i = stu
            h_new = s_i * h1 + t_i * h2 + u_i
            return (h_new, h1), h_new

        zeros = jnp.zeros(u.shape[1:], dtype)
        init = (zeros, zeros) if h0 is None else h0
        _, h = jax.lax.scan(step, init, (s, t, u), reverse=reverse,
                            unroll=unroll)
        return h

    if method == "assoc":
        # 2x2 companion-matrix associative scan:
        #   H_i = [[s_i, t_i], [1, 0]] H_{i-1} + [u_i, 0],  H = (h_i, h_{i-1}).
        s = jnp.broadcast_to(s, u.shape)
        t = jnp.broadcast_to(t, u.shape)
        one = jnp.ones_like(s)
        zero = jnp.zeros_like(s)
        # A: (N, 2, 2, ...), b: (N, 2, ...) — move the 2x2 in axes 1,2.
        A = jnp.stack(
            [jnp.stack([s, t], axis=1), jnp.stack([one, zero], axis=1)], axis=1
        )  # (N, 2, 2, ...)
        b = jnp.stack([u, jnp.zeros_like(u)], axis=1)  # (N, 2, ...)

        def matmul2(X, Y):
            # X, Y: (k, 2, 2, ...) — contract the inner 2-dims explicitly.
            return jnp.stack(
                [
                    jnp.stack(
                        [
                            X[:, 0, 0] * Y[:, 0, 0] + X[:, 0, 1] * Y[:, 1, 0],
                            X[:, 0, 0] * Y[:, 0, 1] + X[:, 0, 1] * Y[:, 1, 1],
                        ],
                        axis=1,
                    ),
                    jnp.stack(
                        [
                            X[:, 1, 0] * Y[:, 0, 0] + X[:, 1, 1] * Y[:, 1, 0],
                            X[:, 1, 0] * Y[:, 0, 1] + X[:, 1, 1] * Y[:, 1, 1],
                        ],
                        axis=1,
                    ),
                ],
                axis=1,
            )

        def matvec2(X, v):
            # X: (k, 2, 2, ...), v: (k, 2, ...)
            return jnp.stack(
                [
                    X[:, 0, 0] * v[:, 0] + X[:, 0, 1] * v[:, 1],
                    X[:, 1, 0] * v[:, 0] + X[:, 1, 1] * v[:, 1],
                ],
                axis=1,
            )

        def combine(fst, snd):
            A1, b1 = fst
            A2, b2 = snd
            return matmul2(A2, A1), matvec2(A2, b1) + b2

        AA, bb = jax.lax.associative_scan(combine, (A, b), reverse=reverse,
                                          axis=0)
        if h0 is not None:
            # H_i = AA_i @ H_seed + bb_i with H_seed = (h_{-1}, h_{-2})
            return AA[:, 0, 0] * h0[0] + AA[:, 0, 1] * h0[1] + bb[:, 0]
        return bb[:, 0]

    raise ValueError(f"unknown method {method!r}")
