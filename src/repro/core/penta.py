"""Pentadiagonal LR solver with a single shared LHS (paper §IV).

Diagonals follow the paper's naming for the matrix rows
    a_i x_{i-2} + b_i x_{i-1} + c_i x_i + d_i x_{i+1} + e_i x_{i+2} = f_i
(0-based here; a_0 = a_1 = b_0 = 0 and d_{N-1} = e_{N-2} = e_{N-1} = 0 are
outside the matrix and forced to zero).

Factored form A = L R (Engeln-Müllges & Uhlig; storage O(5N) — the paper's
O(5N + MN) total; the *uniform* variant drops eps for O(4N + MN)):
    eps       = a                       (L sub-sub diagonal)
    beta      (L sub diagonal)
    inv_alpha = 1/alpha                 (L diagonal, stored inverted)
    gamma     (R super diagonal)
    delta     (R super-super diagonal)

Solve:
    L g = f :  g_i = (f_i - eps_i g_{i-2} - beta_i g_{i-1}) * inv_alpha_i
    R x = g :  x_i = g_i - gamma_i x_{i+1} - delta_i x_{i+2}

Periodic boundaries use a rank-4 Woodbury correction (the periodic
pentadiagonal matrix has 2x2 corner blocks, each full-rank, so rank 4 is the
minimum; see DESIGN.md). Like the paper's Sherman-Morrison step, the four
auxiliary solves A' Z = U happen once per operator and are shared by every
system in the batch.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .recurrence import _align, linear_recurrence2


class PentaFactor(NamedTuple):
    eps: jax.Array        # (N,) / (N, M) — equals a; scalar 0-d for uniform mode
    beta: jax.Array
    inv_alpha: jax.Array
    gamma: jax.Array
    delta: jax.Array


class PeriodicPentaFactor(NamedTuple):
    factor: PentaFactor
    Z: jax.Array          # (N, 4)  A'^{-1} U
    Minv: jax.Array       # (4, 4)  (I + V^T Z)^{-1}
    vcoef: jax.Array      # (6,) corner coefficients [a0, b0, a1, eN2, dN1, eN1]
    Zt: jax.Array         # (N, 4)  A'^{-T} V — the adjoint's corner aux


def penta_factor(a, b, c, d, e, *, unroll: int = 1) -> PentaFactor:
    """LR factorisation (paper §IV.A steps 1-14), single ``lax.scan``."""
    a = jnp.asarray(a); b = jnp.asarray(b); c = jnp.asarray(c)
    d = jnp.asarray(d); e = jnp.asarray(e)
    # entries outside the band (wrap entries of the periodic problem) are not
    # part of the core matrix — force them to zero for robustness.
    a = a.at[jnp.array([0, 1])].set(0)
    b = b.at[0].set(0)
    d = d.at[-1].set(0)
    e = e.at[jnp.array([-2, -1])].set(0)

    def step(carry, abcde):
        g1, g2, d1, d2 = carry  # gamma_{i-1}, gamma_{i-2}, delta_{i-1}, delta_{i-2}
        a_i, b_i, c_i, d_i, e_i = abcde
        beta_i = b_i - a_i * g2
        alpha_i = c_i - a_i * d2 - beta_i * g1
        inv_i = 1.0 / alpha_i
        gamma_i = (d_i - beta_i * d1) * inv_i
        delta_i = e_i * inv_i
        return (gamma_i, g1, delta_i, d1), (beta_i, inv_i, gamma_i, delta_i)

    zero = jnp.zeros_like(c[0])
    _, (beta, inv_alpha, gamma, delta) = jax.lax.scan(
        step, (zero, zero, zero, zero), (a, b, c, d, e), unroll=unroll
    )
    # entries beyond the band are mathematically unused; zero them so storage
    # accounting and the uniform variant stay exact.
    gamma = gamma.at[-1].set(0)
    delta = delta.at[jnp.array([-2, -1])].set(0)
    return PentaFactor(eps=a, beta=beta, inv_alpha=inv_alpha, gamma=gamma, delta=delta)


def penta_solve(f: PentaFactor, rhs: jax.Array, *,
                method: str = "scan", unroll: int = 1) -> jax.Array:
    """Solve A x = rhs given the LR factorisation. rhs: (N,) or (N, M...)."""
    rhs = jnp.asarray(rhs)
    eps = _align(jnp.broadcast_to(f.eps, f.beta.shape), rhs)
    beta = _align(f.beta, rhs)
    inv_alpha = _align(f.inv_alpha, rhs)
    gamma = _align(f.gamma, rhs)
    delta = _align(f.delta, rhs)

    # L g = f : g_i = (-beta_i inv_i) g_{i-1} + (-eps_i inv_i) g_{i-2} + f_i inv_i
    g = linear_recurrence2(-beta * inv_alpha, -eps * inv_alpha, rhs * inv_alpha,
                           method=method, unroll=unroll)
    # R x = g : x_i = (-gamma_i) x_{i+1} + (-delta_i) x_{i+2} + g_i
    x = linear_recurrence2(-gamma, -delta, g, reverse=True,
                           method=method, unroll=unroll)
    return x


def penta_solve_t(f: PentaFactor, g: jax.Array, *,
                  method: str = "scan", unroll: int = 1) -> jax.Array:
    """Solve the TRANSPOSED system A^T x = g from the SAME LR factorisation.

    A = L R (L: diagonal 1/inv_alpha, sub beta, sub-sub eps; R: unit diagonal,
    super gamma, super-super delta), so A^T = R^T L^T reuses the stored O(5N)
    factor — no transposed refactorisation:

        R^T y = g :  y_i = g_i - gamma_{i-1} y_{i-1} - delta_{i-2} y_{i-2}
        L^T x = y :  x_i = (y_i - beta_{i+1} x_{i+1} - eps_{i+2} x_{i+2})
                           * inv_alpha_i

    ``f.eps`` must be vector-shaped here (expand uniform-mode factors with
    ``repro.solver.reference.expand_uniform`` first, exactly as for the
    forward solve).
    """
    g = jnp.asarray(g)
    eps = _align(jnp.broadcast_to(f.eps, f.beta.shape), g)
    beta = _align(f.beta, g)
    inv_alpha = _align(f.inv_alpha, g)
    gamma = _align(f.gamma, g)
    delta = _align(f.delta, g)

    zero1 = jnp.zeros_like(gamma[:1])
    zero2 = jnp.zeros_like(gamma[:2])
    gamma_prev = jnp.concatenate([zero1, gamma[:-1]], axis=0)   # gamma_{i-1}
    delta_prev2 = jnp.concatenate([zero2, delta[:-2]], axis=0)  # delta_{i-2}
    beta_next = jnp.concatenate([beta[1:], zero1], axis=0)      # beta_{i+1}
    eps_next2 = jnp.concatenate([eps[2:], zero2], axis=0)       # eps_{i+2}

    y = linear_recurrence2(-gamma_prev, -delta_prev2, g,
                           method=method, unroll=unroll)
    x = linear_recurrence2(-beta_next * inv_alpha, -eps_next2 * inv_alpha,
                           y * inv_alpha, reverse=True,
                           method=method, unroll=unroll)
    return x


def penta_factor_solve(a, b, c, d, e, rhs, *, method: str = "scan") -> jax.Array:
    """Fused factor+solve (cuPentBatch semantics — re-factors every call)."""
    return penta_solve(penta_factor(a, b, c, d, e), rhs, method=method)


# ---------------------------------------------------------------------------
# Periodic boundaries — rank-4 Woodbury
# ---------------------------------------------------------------------------

def _vty(vcoef: jax.Array, y: jax.Array) -> jax.Array:
    """V^T y for the rank-4 corner correction. y: (N,) or (N, M) -> (4,) / (4, M)."""
    a0, b0, a1, eN2, dN1, eN1 = vcoef
    return jnp.stack(
        [
            a0 * y[-2] + b0 * y[-1],   # v_0: row-0 wrap entries at cols N-2, N-1
            a1 * y[-1],                # v_1: row-1 wrap entry  at col  N-1
            eN2 * y[0],                # v_2: row-(N-2) wrap    at col  0
            dN1 * y[0] + eN1 * y[1],   # v_3: row-(N-1) wraps   at cols 0, 1
        ],
        axis=0,
    )


def periodic_penta_factor(a, b, c, d, e) -> PeriodicPentaFactor:
    """Factor the periodic pentadiagonal operator.

    Corner entries of the periodic matrix P (0-based):
        P[0, N-2] = a_0, P[0, N-1] = b_0, P[1, N-1] = a_1,
        P[N-2, 0] = e_{N-2}, P[N-1, 0] = d_{N-1}, P[N-1, 1] = e_{N-1}.
    P = A' + U V^T with U = [e_0, e_1, e_{N-2}, e_{N-1}] and V as in ``_vty``
    (disjoint row/column supports -> A' is the plain truncated band, no
    diagonal modification, preserving diagonal dominance).
    """
    a = jnp.asarray(a); b = jnp.asarray(b); c = jnp.asarray(c)
    d = jnp.asarray(d); e = jnp.asarray(e)
    n = c.shape[0]
    vcoef = jnp.stack([a[0], b[0], a[1], e[-2], d[-1], e[-1]])

    f = penta_factor(a, b, c, d, e)
    U = jnp.zeros((n, 4), c.dtype)
    U = U.at[0, 0].set(1.0).at[1, 1].set(1.0).at[-2, 2].set(1.0).at[-1, 3].set(1.0)
    Z = penta_solve(f, U)                      # (N, 4)
    M4 = jnp.eye(4, dtype=c.dtype) + _vty(vcoef, Z)  # (4, 4)
    # the adjoint's auxiliary solves A'^{-T} V, also once per operator
    Zt = penta_solve_t(f, _corner_V(vcoef, n))       # (N, 4)
    return PeriodicPentaFactor(factor=f, Z=Z, Minv=jnp.linalg.inv(M4),
                               vcoef=vcoef, Zt=Zt)


def periodic_penta_solve(pf: PeriodicPentaFactor, rhs: jax.Array, *,
                         method: str = "scan", unroll: int = 1) -> jax.Array:
    """x = y - Z (I + V^T Z)^{-1} V^T y  with  y = A'^{-1} rhs."""
    y = penta_solve(pf.factor, rhs, method=method, unroll=unroll)
    w = pf.Minv @ _vty(pf.vcoef, y)            # (4,) or (4, M)
    return y - jnp.tensordot(pf.Z, w, axes=([1], [0]))


def _corner_V(vcoef: jax.Array, n: int) -> jax.Array:
    """Materialise V (N, 4) of the rank-4 correction P = A' + U V^T."""
    a0, b0, a1, eN2, dN1, eN1 = vcoef
    V = jnp.zeros((n, 4), vcoef.dtype)
    return (V.at[-2, 0].set(a0).at[-1, 0].set(b0)
             .at[-1, 1].set(a1)
             .at[0, 2].set(eN2)
             .at[0, 3].set(dN1).at[1, 3].set(eN1))


def periodic_corner_correction_t(pf: PeriodicPentaFactor,
                                 y: jax.Array) -> jax.Array:
    """Transposed rank-4 Woodbury corner step on y = A'^{-T} g.

    P = A' + U V^T, so P^T = A'^T + V U^T and Woodbury gives
        x = y - Zt (I + U^T A'^{-T} V)^{-1} U^T y,
    with Zt = A'^{-T} V (solved once at factor time, like the forward's
    Z).  Since U^T A'^{-T} V = (V^T Z)^T, the 4x4 inverse is just the
    stored ``Minv`` transposed — the adjoint needs no second LHS.  Shared
    by the reference transposed solve below and the ``pallas`` backend's
    kernel-produced y — ONE home for the corner algebra.
    """
    uty = jnp.stack([y[0], y[1], y[-2], y[-1]], axis=0)            # U^T y
    h = pf.Minv.T @ uty
    return y - jnp.tensordot(pf.Zt, h, axes=([1], [0]))


def periodic_penta_solve_t(pf: PeriodicPentaFactor, g: jax.Array, *,
                           method: str = "scan", unroll: int = 1) -> jax.Array:
    """Transposed periodic penta solve P^T x = g from the SAME stored
    factor (see ``periodic_corner_correction_t`` for the corner algebra)."""
    y = penta_solve_t(pf.factor, g, method=method, unroll=unroll)
    return periodic_corner_correction_t(pf, y)


def dense_penta(a, b, c, d, e, periodic: bool = False) -> jax.Array:
    """Materialise the (N, N) matrix — test oracle only."""
    a = jnp.asarray(a); b = jnp.asarray(b); c = jnp.asarray(c)
    d = jnp.asarray(d); e = jnp.asarray(e)
    n = c.shape[0]
    A = (jnp.diag(c) + jnp.diag(b[1:], -1) + jnp.diag(a[2:], -2)
         + jnp.diag(d[:-1], 1) + jnp.diag(e[:-2], 2))
    if periodic:
        A = (A.at[0, n - 2].add(a[0]).at[0, n - 1].add(b[0])
              .at[1, n - 1].add(a[1])
              .at[n - 2, 0].add(e[n - 2])
              .at[n - 1, 0].add(d[n - 1]).at[n - 1, 1].add(e[n - 1]))
    return A
