"""Paper core: constant-LHS interleaved batch banded solvers (pure JAX).

Gloster, Carroll, Bustamante, Ó Náraigh — "Efficient Interleaved Batch Matrix
Solvers for CUDA" (2019). See DESIGN.md for the CUDA→TPU adaptation.

These are the low-level factor/solve pairs.  The canonical public entry
point is ``repro.solver`` (DESIGN.md §5): build a ``BandedSystem`` and call
``plan(system, backend=...)`` — the ``reference`` backend dispatches to the
functions in this package.  ``TridiagOperator`` / ``PentaOperator`` are
deprecated shims over that front-end.
"""

from .banded import PentaOperator, TridiagOperator
from .penta import (
    PentaFactor,
    PeriodicPentaFactor,
    dense_penta,
    penta_factor,
    penta_factor_solve,
    penta_solve,
    penta_solve_t,
    periodic_penta_factor,
    periodic_penta_solve,
    periodic_penta_solve_t,
)
from .recurrence import linear_recurrence, linear_recurrence2
from .tridiag import (
    PeriodicTridiagFactor,
    TridiagFactor,
    dense_tridiag,
    periodic_thomas_factor,
    periodic_thomas_solve,
    periodic_thomas_solve_t,
    thomas_factor,
    thomas_factor_solve,
    thomas_solve,
    thomas_solve_t,
)

__all__ = [
    "PentaFactor", "PentaOperator", "PeriodicPentaFactor",
    "PeriodicTridiagFactor", "TridiagFactor", "TridiagOperator",
    "dense_penta", "dense_tridiag",
    "linear_recurrence", "linear_recurrence2",
    "penta_factor", "penta_factor_solve", "penta_solve", "penta_solve_t",
    "periodic_penta_factor", "periodic_penta_solve", "periodic_penta_solve_t",
    "periodic_thomas_factor", "periodic_thomas_solve",
    "periodic_thomas_solve_t",
    "thomas_factor", "thomas_factor_solve", "thomas_solve", "thomas_solve_t",
]
