"""cuSten-equivalent: periodic finite-difference stencils on interleaved
(N, M) field batches (the paper computes its CN right-hand sides with
cuSten [13]; this is the JAX analogue)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_periodic_stencil(field: jax.Array, weights) -> jax.Array:
    """Apply a centred periodic stencil along axis 0 of ``field``.

    field:   (N, ...) interleaved batch (N = grid axis).
    weights: sequence of length 2r+1 (offset -r..+r).
    """
    weights = list(weights)
    r = (len(weights) - 1) // 2
    out = jnp.zeros_like(field)
    for k, w in enumerate(weights):
        off = k - r
        if w == 0:
            continue
        out = out + w * jnp.roll(field, -off, axis=0)
    return out


def cn_rhs_diffusion(field: jax.Array, sigma: float) -> jax.Array:
    """Paper Eq. (9) RHS: sigma C_{i-1} + (1-2 sigma) C_i + sigma C_{i+1}."""
    return apply_periodic_stencil(field, [sigma, 1.0 - 2.0 * sigma, sigma])


def cn_rhs_hyperdiffusion(field: jax.Array, sigma: float) -> jax.Array:
    """Paper Eq. (20b) RHS:
    -sigma C_{i-2} + 4 sigma C_{i-1} + (1-6 sigma) C_i + 4 sigma C_{i+1} - sigma C_{i+2}."""
    return apply_periodic_stencil(
        field, [-sigma, 4.0 * sigma, 1.0 - 6.0 * sigma, 4.0 * sigma, -sigma])
