"""Batched periodic 1-D diffusion, Crank-Nicolson (paper §III.B-D).

    dC/dt = alpha d2C/dx2,  C(x+L) = C(x),  alpha = L = 1 after rescaling.

Implicit LHS (Eq. 11): a_i = -sigma, b_i = 1+2 sigma, c_i = -sigma with
sigma = dt / (2 dx^2); the LHS is IDENTICAL for every system in the batch —
exactly the paper's single-LHS setting.

Solves route through the transformation-native ``repro.solver`` API:
``factorize`` builds ONE ``Factorization`` pytree per stepper, the
``lax.scan`` time loop closes over it as a constant, and ``solve`` is
traced exactly once for the whole integration — no Python re-dispatch per
step, and the trajectory is differentiable end-to-end (the adjoint of
every step reuses the same stored factor).  Flipping backends is one
argument:

  * ``backend="reference"`` (alias ``"core"``) — pure-JAX scan solver.
  * ``backend="pallas"``   — cuThomasConstantBatch Pallas kernel, periodic
    correction applied outside (paper-faithful 2-kernel pipeline).
  * ``backend="sharded"``  — systems sharded over a device mesh.
  * ``backend="auto"``     — pallas when the working set fits VMEM, else
    reference.
  * ``backend="fused"``    — single fused Pallas kernel (beyond-paper; not
    a registry backend, kept as the fused-step special case).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import periodic_thomas_factor
from repro.kernels import fused_cn_step
from repro.solver import BandedSystem, factorize, solve
from .stencil import cn_rhs_diffusion


@dataclasses.dataclass(frozen=True)
class DiffusionCN:
    n: int
    dt: float
    backend: str = "reference"   # reference|core | pallas | sharded | auto | fused
    dtype: object = jnp.float32

    @property
    def dx(self) -> float:
        return 1.0 / self.n

    @property
    def sigma(self) -> float:
        return self.dt / (2.0 * self.dx * self.dx)

    def system(self) -> BandedSystem:
        s = self.sigma
        return BandedSystem.tridiag(-s, 1.0 + 2.0 * s, -s, n=self.n,
                                    periodic=True, dtype=self.dtype)

    def factor(self):
        s = self.sigma
        a = jnp.full((self.n,), -s, self.dtype)
        b = jnp.full((self.n,), 1.0 + 2.0 * s, self.dtype)
        c = jnp.full((self.n,), -s, self.dtype)
        return periodic_thomas_factor(a, b, c)

    def step_fn(self):
        """Returns (factorization, step) where step(field (N, M)) -> next.

        The factorization is built ONCE here; ``step`` closes over it, so a
        ``lax.scan`` (or jit) tracing ``step`` sees it as a constant — the
        paper's factor-once reuse, extended across the whole time loop.
        """
        s = self.sigma

        if self.backend == "fused":
            pf = self.factor()

            def step(field):
                return fused_cn_step(pf, s, field)
            return pf, step

        fact = factorize(self.system(), backend=self.backend)

        def step(field):
            return solve(fact, cn_rhs_diffusion(field, s))
        return fact, step

    def run(self, field0: jax.Array, n_steps: int, *, use_scan: bool = True):
        """Integrate n_steps. field0: (N, M).

        ``use_scan=True`` (default, all backends): one ``lax.scan`` over the
        closed-over factorization — factor once, the solve is traced exactly
        once, and thousands of steps run inside one compiled program.
        ``use_scan=False`` keeps the step-by-step Python loop (re-traces the
        solve every step; useful for debugging single steps).
        """
        _, step = self.step_fn()
        if use_scan:
            def body(f, _):
                return step(f), None
            out, _ = jax.lax.scan(body, field0, None, length=n_steps)
            return out
        f = field0
        for _ in range(n_steps):
            f = step(f)
        return f

    @staticmethod
    def analytic(x: np.ndarray, t: float, k: int = 1) -> np.ndarray:
        """C(x,0) = sin(2 pi k x)  ->  exp(-4 pi^2 k^2 t) sin(2 pi k x)."""
        return np.exp(-4.0 * np.pi ** 2 * k ** 2 * t) * np.sin(2 * np.pi * k * x)
