"""PDE substrate: the paper's application layer.

Batched 1-D Crank-Nicolson integration of the diffusion (paper §III.B) and
hyperdiffusion (paper §IV.B) equations on periodic domains, plus a 2-D ADI
scheme (paper §I motivates both). The RHS stencils are the cuSten-equivalent
(``stencil.py``); the implicit solves are the paper's constant-LHS batch
solvers.
"""

from .diffusion import DiffusionCN
from .hyperdiffusion import HyperdiffusionCN
from .adi2d import ADI2D
from .stencil import apply_periodic_stencil

__all__ = ["ADI2D", "DiffusionCN", "HyperdiffusionCN", "apply_periodic_stencil"]
