"""Batched periodic 1-D hyperdiffusion, Crank-Nicolson (paper §IV.B-C).

    dC/dt = -D d4C/dx4,  periodic,  D = L = 1 after rescaling.

Implicit LHS (Eq. 20a): a_i = e_i = sigma, b_i = d_i = -4 sigma,
c_i = 1 + 6 sigma with sigma = dt / (2 dx^4) — a *uniform* pentadiagonal
operator, so all three paper variants apply (cuPentBatch baseline,
cuPentConstantBatch, cuPentUniformBatch).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PentaOperator
from repro.kernels import penta_constant
from .stencil import cn_rhs_hyperdiffusion


@dataclasses.dataclass(frozen=True)
class HyperdiffusionCN:
    n: int
    dt: float
    backend: str = "core"       # core | pallas
    mode: str = "constant"      # constant | uniform | batch (baseline)
    batch: int | None = None    # required for mode="batch"
    dtype: object = jnp.float32

    @property
    def dx(self) -> float:
        return 1.0 / self.n

    @property
    def sigma(self) -> float:
        return self.dt / (2.0 * self.dx ** 4)

    def coefficients(self):
        s = self.sigma
        return (s, -4.0 * s, 1.0 + 6.0 * s, -4.0 * s, s)

    def operator(self) -> PentaOperator:
        return PentaOperator.create(*self.coefficients(), n=self.n,
                                    mode=self.mode, periodic=True,
                                    batch=self.batch, dtype=self.dtype)

    def step_fn(self):
        op = self.operator()
        s = self.sigma

        if self.backend == "core":
            def step(field):
                return op.solve(cn_rhs_hyperdiffusion(field, s))
        elif self.backend == "pallas":
            if self.mode == "batch":
                raise ValueError("pallas backend benchmarks use constant/uniform")
            pf = op._factor_for_solve()  # PeriodicPentaFactor
            inner, Z, Minv, vcoef = pf.factor, pf.Z, pf.Minv, pf.vcoef

            def step(field):
                rhs = cn_rhs_hyperdiffusion(field, s)
                y = penta_constant(inner, rhs, uniform=(self.mode == "uniform"))
                # rank-4 Woodbury correction (cheap: 4xM dots)
                from repro.core.penta import _vty
                w = Minv @ _vty(vcoef, y)
                return y - jnp.tensordot(Z, w, axes=([1], [0]))
        else:
            raise ValueError(f"unknown backend {self.backend!r}")
        return op, step

    def run(self, field0: jax.Array, n_steps: int, *, use_scan: bool = True):
        _, step = self.step_fn()
        if use_scan and self.backend == "core":
            out, _ = jax.lax.scan(lambda f, _: (step(f), None), field0,
                                  None, length=n_steps)
            return out
        f = field0
        for _ in range(n_steps):
            f = step(f)
        return f

    @staticmethod
    def analytic(x: np.ndarray, t: float, k: int = 1) -> np.ndarray:
        """C(x,0) = sin(2 pi k x) -> exp(-(2 pi k)^4 t) sin(2 pi k x)."""
        return np.exp(-((2 * np.pi * k) ** 4) * t) * np.sin(2 * np.pi * k * x)
