"""Batched periodic 1-D hyperdiffusion, Crank-Nicolson (paper §IV.B-C).

    dC/dt = -D d4C/dx4,  periodic,  D = L = 1 after rescaling.

Implicit LHS (Eq. 20a): a_i = e_i = sigma, b_i = d_i = -4 sigma,
c_i = 1 + 6 sigma with sigma = dt / (2 dx^4) — a *uniform* pentadiagonal
operator, so all three paper variants apply (cuPentBatch baseline,
cuPentConstantBatch, cuPentUniformBatch).

Solves route through the transformation-native ``repro.solver`` API:
``factorize`` once per stepper, the ``lax.scan`` time loop closes over the
``Factorization`` pytree, the solve is traced exactly once per
integration, and the whole trajectory is differentiable (the adjoint
reuses the same stored factor).  ``backend`` is any registry name
(``reference`` — alias ``core`` —, ``pallas``, ``sharded``) or ``auto``;
``mode`` selects the paper's storage variant (``constant`` | ``uniform`` |
``batch``).  The pallas path applies the rank-4 Woodbury corner correction
outside the kernel, inside the solve.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.solver import BandedSystem, factorize, solve
from .stencil import cn_rhs_hyperdiffusion


@dataclasses.dataclass(frozen=True)
class HyperdiffusionCN:
    n: int
    dt: float
    backend: str = "reference"  # reference|core | pallas | sharded | auto
    mode: str = "constant"      # constant | uniform | batch (baseline)
    batch: int | None = None    # required for mode="batch"
    dtype: object = jnp.float32

    @property
    def dx(self) -> float:
        return 1.0 / self.n

    @property
    def sigma(self) -> float:
        return self.dt / (2.0 * self.dx ** 4)

    def coefficients(self):
        s = self.sigma
        return (s, -4.0 * s, 1.0 + 6.0 * s, -4.0 * s, s)

    def system(self) -> BandedSystem:
        return BandedSystem.penta(*self.coefficients(), n=self.n,
                                  periodic=True, mode=self.mode,
                                  batch=self.batch, dtype=self.dtype)

    def step_fn(self):
        """Returns (factorization, step); step closes over the factor."""
        fact = factorize(self.system(), backend=self.backend)
        s = self.sigma

        def step(field):
            return solve(fact, cn_rhs_hyperdiffusion(field, s))
        return fact, step

    def run(self, field0: jax.Array, n_steps: int, *, use_scan: bool = True):
        """Integrate n_steps: factor once, scan the solve (all backends)."""
        _, step = self.step_fn()
        if use_scan:
            out, _ = jax.lax.scan(lambda f, _: (step(f), None), field0,
                                  None, length=n_steps)
            return out
        f = field0
        for _ in range(n_steps):
            f = step(f)
        return f

    @staticmethod
    def analytic(x: np.ndarray, t: float, k: int = 1) -> np.ndarray:
        """C(x,0) = sin(2 pi k x) -> exp(-(2 pi k)^4 t) sin(2 pi k x)."""
        return np.exp(-((2 * np.pi * k) ** 4) * t) * np.sin(2 * np.pi * k * x)
