"""2-D ADI (alternating-direction implicit) diffusion on a periodic grid —
the paper's §I motivating application for batched tridiagonal solves.

Peaceman-Rachford splitting of  dC/dt = alpha (d2/dx2 + d2/dy2) C :

    (1 - sx Dxx) C*      = (1 + sy Dyy) C^n        (x-implicit half step)
    (1 - sy Dyy) C^{n+1} = (1 + sx Dxx) C*         (y-implicit half step)

with s = alpha dt / (2 h^2). Each half step is a BATCH of 1-D periodic
tridiagonal solves sharing one LHS — the x-sweep batches over y (and any
field batch), the y-sweep over x. This is exactly the "single LHS, many
interleaved RHS" shape the paper optimises.

Both sweeps route through the transformation-native ``repro.solver`` API:
the x- and y-operators are factored ONCE into ``Factorization`` pytrees and
the ``lax.scan`` time loop closes over both, so each half-step solve is
traced once per integration and the whole 2-D trajectory differentiates
through ``jax.grad`` (each adjoint half-step reuses its forward factor).
``backend`` takes any registry name (``reference`` — alias ``core`` —,
``pallas``, ``sharded``) or ``auto``, so the same 2-D stepper retargets
across execution backends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.solver import BandedSystem, factorize, solve
from .stencil import apply_periodic_stencil


@dataclasses.dataclass(frozen=True)
class ADI2D:
    nx: int
    ny: int
    dt: float
    alpha: float = 1.0
    backend: str = "reference"
    dtype: object = jnp.float32

    @property
    def sx(self) -> float:
        return self.alpha * self.dt / (2.0 * (1.0 / self.nx) ** 2)

    @property
    def sy(self) -> float:
        return self.alpha * self.dt / (2.0 * (1.0 / self.ny) ** 2)

    def _factorize(self, n, s):
        system = BandedSystem.tridiag(-s, 1.0 + 2.0 * s, -s, n=n,
                                      periodic=True, dtype=self.dtype)
        return factorize(system, backend=self.backend)

    def step_fn(self):
        fx = self._factorize(self.nx, self.sx)
        fy = self._factorize(self.ny, self.sy)
        sx, sy = self.sx, self.sy

        def step(field):
            """field: (NX, NY) or (NX, NY, B)."""
            # x-implicit: RHS = (1 + sy Dyy) C  (apply along y)
            cy = field.reshape(field.shape[0], field.shape[1], -1)
            rhs = cy + sy * apply_periodic_stencil(
                jnp.moveaxis(cy, 1, 0), [1.0, -2.0, 1.0]).swapaxes(0, 1)
            c_star = solve(fx, rhs.reshape(field.shape[0], -1))
            c_star = c_star.reshape(cy.shape)
            # y-implicit: RHS = (1 + sx Dxx) C*  (apply along x)
            rhs2 = c_star + sx * apply_periodic_stencil(c_star, [1.0, -2.0, 1.0])
            rhs2_t = jnp.moveaxis(rhs2, 1, 0)                 # (NY, NX, B)
            c_next = solve(fy, rhs2_t.reshape(field.shape[1], -1))
            c_next = jnp.moveaxis(c_next.reshape(rhs2_t.shape), 0, 1)
            return c_next.reshape(field.shape)

        return step

    def run(self, field0: jax.Array, n_steps: int):
        step = self.step_fn()
        out, _ = jax.lax.scan(lambda f, _: (step(f), None), field0,
                              None, length=n_steps)
        return out

    @staticmethod
    def analytic(x, y, t, kx: int = 1, ky: int = 1, alpha: float = 1.0):
        """C0 = sin(2 pi kx x) sin(2 pi ky y) -> decay exp(-4 pi^2 (kx^2+ky^2) alpha t)."""
        decay = np.exp(-4 * np.pi ** 2 * (kx ** 2 + ky ** 2) * alpha * t)
        return decay * np.sin(2 * np.pi * kx * x) * np.sin(2 * np.pi * ky * y)
