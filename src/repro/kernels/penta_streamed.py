"""HBM-streamed (split-N) batched pentadiagonal LR solve — constant LHS.

Split-N analogue of ``penta_constant_kernel`` (see ``thomas_streamed.py``
for the grid/carry scheme): a 2-D grid ``(M/BLOCK_M, N/BLOCK_N)`` streams
RHS chunks through VMEM while the *second-order* sweep state — the two
forward carries (g_{i−1}, g_{i−2}) and the two backward carries
(x_{i+1}, x_{i+2}) — rides a ``(2, BLOCK_M)`` VMEM scratch across the
sequential N-chunk grid steps.

Boundary rows fall out of the general recurrence with zero-initialised
carries because ``penta_factor`` forces the out-of-band entries to zero
(a_0 = a_1 = beta_0 = 0; gamma_{N−1} = delta_{N−2} = delta_{N−1} = 0), so
neither kernel special-cases its first/last two rows, and zero-padding N
to a BLOCK_N multiple is exact and NaN-free.

The cuPentUniformBatch variant (all-equal diagonals) drops the eps row
from the streamed LHS — (4, BLOCK_N) chunks — and reads eps from a (1, 1)
parameter block instead.  eps arrives as an ARRAY operand, never a Python
float baked into the kernel closure, so uniform-mode solves stay jittable
with a traced ``Factorization`` (no ``ConcretizationTypeError`` inside
``jax.jit``/``lax.scan``).
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (chunk_lhs_spec, chunk_spec, reset_carry, row, scalar,
                     store_row)
from .penta import BETA, DELTA, EPS, GAMMA, INV_ALPHA


def penta_streamed_fwd_kernel(*refs, block_n: int, unroll: int,
                              uniform: bool):
    """Forward L-sweep over ascending chunks.

    refs (uniform): eps_ref (1, 1), lhs_ref (4, BLOCK_N), f_ref, g_ref,
    carry_ref (2, BLOCK_M) = [g_{i−1}, g_{i−2}].
    refs (full): lhs_ref (5, BLOCK_N), f_ref, g_ref, carry_ref."""
    if uniform:
        eps_ref, lhs_ref, f_ref, g_ref, carry_ref = refs
        off = -1
        eps_at = lambda i: eps_ref[0, 0]
    else:
        lhs_ref, f_ref, g_ref, carry_ref = refs
        off = 0
        eps_at = lambda i: scalar(lhs_ref, EPS, i)
    m = f_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))

    def fwd(i, carry):
        gm1, gm2 = carry
        g = (row(f_ref, i, m) - eps_at(i) * gm2
             - scalar(lhs_ref, BETA + off, i) * gm1) \
            * scalar(lhs_ref, INV_ALPHA + off, i)
        store_row(g_ref, i, g)
        return g, gm1

    gm1, gm2 = jax.lax.fori_loop(
        0, block_n, fwd, (row(carry_ref, 0, m), row(carry_ref, 1, m)),
        unroll=unroll)
    store_row(carry_ref, 0, gm1)
    store_row(carry_ref, 1, gm2)


def penta_streamed_bwd_kernel(lhs_ref, g_ref, x_ref, carry_ref, *,
                              block_n: int, unroll: int, uniform: bool):
    """Backward R-sweep over descending chunks; carry = [x_{i+1}, x_{i+2}]."""
    off = -1 if uniform else 0
    m = g_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))

    def bwd(t, carry):
        xp1, xp2 = carry
        i = block_n - 1 - t
        x_i = (row(g_ref, i, m)
               - scalar(lhs_ref, GAMMA + off, i) * xp1
               - scalar(lhs_ref, DELTA + off, i) * xp2)
        store_row(x_ref, i, x_i)
        return x_i, xp1

    xp1, xp2 = jax.lax.fori_loop(
        0, block_n, bwd, (row(carry_ref, 0, m), row(carry_ref, 1, m)),
        unroll=unroll)
    store_row(carry_ref, 0, xp1)
    store_row(carry_ref, 1, xp2)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "unroll",
                                    "interpret", "uniform"))
def penta_constant_streamed_pallas(lhs: jax.Array, f: jax.Array, *,
                                   block_m: int = 128, block_n: int = 512,
                                   unroll: int = 1, interpret: bool = True,
                                   uniform: bool = False,
                                   eps: jax.Array | None = None) -> jax.Array:
    """lhs: (5, N) [eps, beta, inv_alpha, gamma, delta] — (4, N) without the
    eps row when ``uniform`` (then ``eps`` is a (1, 1) array operand);
    f: (N, M).  Requires N % block_n == 0 and M % block_m == 0."""
    n, m = f.shape
    rows = 4 if uniform else 5
    num_n = n // block_n
    grid = (m // block_m, num_n)
    carry = [pltpu.VMEM((2, block_m), f.dtype)]

    fwd_specs = [chunk_lhs_spec(rows, block_n, num_n),
                 chunk_spec(block_n, block_m, num_n)]
    fwd_args = [lhs, f]
    if uniform:
        fwd_specs.insert(0, pl.BlockSpec((1, 1), lambda j, k: (0, 0)))
        fwd_args.insert(0, eps)

    g = pl.pallas_call(
        functools.partial(penta_streamed_fwd_kernel, block_n=block_n,
                          unroll=unroll, uniform=uniform),
        grid=grid,
        in_specs=fwd_specs,
        out_specs=chunk_spec(block_n, block_m, num_n),
        out_shape=jax.ShapeDtypeStruct((n, m), f.dtype),
        scratch_shapes=carry,
        interpret=interpret,
    )(*fwd_args)

    return pl.pallas_call(
        functools.partial(penta_streamed_bwd_kernel, block_n=block_n,
                          unroll=unroll, uniform=uniform),
        grid=grid,
        in_specs=[chunk_lhs_spec(rows, block_n, num_n, reverse=True),
                  chunk_spec(block_n, block_m, num_n, reverse=True)],
        out_specs=chunk_spec(block_n, block_m, num_n, reverse=True),
        out_shape=jax.ShapeDtypeStruct((n, m), f.dtype),
        scratch_shapes=carry,
        interpret=interpret,
    )(lhs, g)
