"""Pallas TPU kernels for the paper's batched banded solvers.

Validated in ``interpret=True`` mode on CPU (this container); compiled for
TPU in production. See DESIGN.md §2 for the CUDA→TPU layout mapping.

These are raw kernel entry points.  The canonical way to reach them is the
``pallas`` backend of ``repro.solver`` (DESIGN.md §5), which adds factor
construction, periodic corner corrections, and VMEM-aware ``block_m``
auto-tuning on top: ``plan(system, backend="pallas").solve(rhs)``.
"""

from .ops import (
    fused_cn_penta_step,
    fused_cn_step,
    penta_batch,
    penta_constant,
    sharded_solve,
    solver_hbm_traffic_bytes,
    stack_penta_lhs,
    stack_tridiag_lhs,
    thomas_batch,
    thomas_constant,
)

__all__ = [
    "fused_cn_penta_step", "fused_cn_step", "penta_batch", "penta_constant",
    "sharded_solve", "solver_hbm_traffic_bytes", "stack_penta_lhs",
    "stack_tridiag_lhs", "thomas_batch", "thomas_constant",
]
