"""Pallas TPU kernels for the paper's batched banded solvers.

Validated in ``interpret=True`` mode on CPU (this container); compiled for
TPU in production. See DESIGN.md §2 for the CUDA→TPU layout mapping.
"""

from .ops import (
    fused_cn_penta_step,
    fused_cn_step,
    penta_batch,
    penta_constant,
    sharded_solve,
    stack_penta_lhs,
    stack_tridiag_lhs,
    thomas_batch,
    thomas_constant,
)

__all__ = [
    "fused_cn_penta_step", "fused_cn_step", "penta_batch", "penta_constant",
    "sharded_solve", "stack_penta_lhs", "stack_tridiag_lhs", "thomas_batch",
    "thomas_constant",
]
