"""The declarative sweep-kernel engine behind every banded Pallas solver.

The paper's whole kernel family — cuThomasConstantBatch, cuThomasBatch,
cuPentConstantBatch, cuPentUniformBatch, cuPentBatch, plus their transposed
(adjoint) and HBM-streamed (split-N) relatives — is ONE algorithm: a
two-pass sweep where each pass is a short linear recurrence

    out_i = (in_i - sum_j coeff_j(i) * carry_j) * scale(i)

with a carry of order 1 (tridiagonal) or 2 (pentadiagonal), walked either
ascending (forward substitution) or descending (back substitution).  Only
*which coefficient rows feed which carry lag* and *where the stored
inverse-diagonal scale sits* differ between variants — the forward solve
scales the forward pass, the transposed solve scales the backward pass,
the uniform variant reads one coefficient from a (1, 1) parameter block.

This module makes that observation executable (DESIGN.md §2.2):

  * ``SweepSpec`` — the declarative description of one solver variant:
    bandwidth (3|5), layout (``shared`` factored LHS vs ``batch`` per-lane
    fused factorisation), ``transposed``, ``streamed`` (VMEM-resident vs
    HBM-streamed split-N), ``uniform`` (penta shared only).
  * ``PassSpec`` — one pass of the sweep: the ``(coefficient row,
    carry lag)`` terms in subtraction order plus an optional scale row.
    ``SweepSpec.passes()`` looks both passes up in ``_PASS_TABLE`` — the
    spec tables that replaced four hand-written kernel modules.
  * ``shared_solver(spec)`` / ``batch_solver(spec)`` — the generic kernel
    builders.  They own the grid layout, the ``chunk_spec`` index maps,
    the VMEM carry scratch, the ``reset_carry`` zero-init (which makes the
    boundary rows fall out of the general recurrence — no first/last-row
    special cases anywhere), and emit the ``pl.pallas_call`` pair.
  * ``REGISTRY`` — every variant the engine generates, by name.  Traffic
    (``SweepSpec.traffic_bytes``) and VMEM accounting
    (``SweepSpec.vmem_counts``) are derived from the spec, so a new
    variant can never silently miss the roofline model or the budget
    check.

Generated bodies are arithmetic-identical (bit-exact) to the hand-written
kernels they replaced: the subtraction order inside each pass and the
zero-carry boundary handling reproduce the old instruction sequences
exactly (``x - 0*c == x`` bitwise for finite ``c``).

Transposed-shared variants run the adjoint sweeps of DESIGN.md §5.1 from
the SAME stored factor: A = L·U means A^T = U^T·L^T, so the transposed
kernels read *shifted* coefficient rows (``c_hat_{i-1}``, ``a_{i+1}``, …)
that the dispatcher pre-shifts on the host (``repro.kernels.ops``).
Transposed-``batch`` needs no kernel of its own — rolling the per-lane
diagonals turns A^T into another batch system, so ``ops``/the solver
backend reuse the forward batch kernels (there is deliberately no
``transposed=True`` batch spec).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import chunk_lhs_spec, chunk_spec, fused_chunk_spec, \
    fused_lhs_spec, _imin, reset_carry, row, scalar, store_row

# Sentinel coefficient source: the uniform-mode eps value, which rides in a
# (1, 1) ARRAY operand (never a Python float baked into the kernel closure,
# so traced Factorization leaves stay jittable — see penta docstrings).
EPS_PARAM = "eps"


@dataclasses.dataclass(frozen=True)
class PassSpec:
    """One pass of a two-pass sweep.

    ``terms`` is a tuple of ``(coeff_src, carry_lag)`` pairs applied in
    SUBTRACTION ORDER (float subtraction is not associative; the order is
    part of the bit-exactness contract with the pre-engine kernels).
    ``coeff_src`` is a row index into the stacked LHS (shared layout) or
    into the per-lane coefficient refs (batch back-substitution), or
    ``EPS_PARAM``.  ``scale`` multiplies the bracketed result (the stored
    inverse diagonal) — ``None`` means the pass is unscaled.
    """

    terms: tuple
    scale: object = None


# (bandwidth, uniform, transposed) -> (forward pass, backward pass).
#
# Shared-layout LHS row conventions (stacked by repro.kernels.ops):
#   tridiag          [a, inv_denom, c_hat]
#   tridiag^T        [c_hat_{i-1}, inv_denom, a_{i+1}]
#   penta            [eps, beta, inv_alpha, gamma, delta]
#   penta uniform    [beta, inv_alpha, gamma, delta]      (+ eps param)
#   penta^T          [delta_{i-2}, gamma_{i-1}, inv_alpha, beta_{i+1},
#                     eps_{i+2}]
#   penta^T uniform  [delta_{i-2}, gamma_{i-1}, inv_alpha, beta_{i+1}]
#                                                         (+ eps param)
# The transposed rows are the SAME stored factor vectors, shifted on the
# host — A^T = U^T L^T from the forward's O(k N) storage, nothing new.
_PASS_TABLE = {
    (3, False, False): (PassSpec(((0, 1),), 1), PassSpec(((2, 1),), None)),
    (3, False, True): (PassSpec(((0, 1),), None), PassSpec(((2, 1),), 1)),
    (5, False, False): (PassSpec(((0, 2), (1, 1)), 2),
                        PassSpec(((3, 1), (4, 2)), None)),
    (5, False, True): (PassSpec(((0, 2), (1, 1)), None),
                       PassSpec(((3, 1), (4, 2)), 2)),
    (5, True, False): (PassSpec(((EPS_PARAM, 2), (0, 1)), 1),
                       PassSpec(((2, 1), (3, 2)), None)),
    (5, True, True): (PassSpec(((0, 2), (1, 1)), None),
                      PassSpec(((3, 1), (EPS_PARAM, 2)), 2)),
}

# Batch-layout back substitution reads the coefficients the fused
# factorisation just produced (c_hat, or gamma/delta), one (N, BLOCK_M)
# per-lane array each.
_BATCH_BWD = {
    1: PassSpec(((0, 1),), None),
    2: PassSpec(((0, 1), (1, 2)), None),
}

# Gated-recurrence passes, by carry order.  A gated linear recurrence
#   h_i = p_i h_{i-1} + q_i          (order 1)
#   h_i = s_i h_{i-1} + t_i h_{i-2} + u_i   (order 2)
# is a banded sweep pass whose multiplicative coefficients are per-token
# GATE OPERANDS — full (N, M) arrays riding the lane axis like the batch
# layout's fused coefficients — instead of rows of a shared stacked LHS.
# Term convention: gate operand index == carry lag - 1 (the lag-1 gate is
# operand 0, the lag-2 gate operand 1), lags ascending, no scale (gated
# recurrences have no stored inverse diagonal).  The sign flip between
# the sweep's ``acc - coeff*carry`` and the recurrence's ``+`` lives in
# the gate accessor (``_gate_coeff``), which negates on read — IEEE
# negation is exact, so ``q - (-p)*h`` is bitwise ``q + p*h``.
_RECUR_TABLE = {
    1: PassSpec(((0, 1),), None),
    2: PassSpec(((0, 1), (1, 2)), None),
}


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative description of one banded-solver variant."""

    bandwidth: int            # 3 | 5
    layout: str               # "shared" (one factored LHS) | "batch"
    transposed: bool = False  # solve A^T x = rhs from the same factor
    streamed: bool = False    # HBM-streamed split-N vs VMEM-resident
    uniform: bool = False     # penta shared only: eps as a (1, 1) operand
    fused: bool = False       # streamed only: both passes in ONE kernel

    def __post_init__(self):
        if self.bandwidth not in (3, 5):
            raise ValueError(f"bandwidth must be 3 or 5, got {self.bandwidth}")
        if self.layout not in ("shared", "batch"):
            raise ValueError(f"unknown layout {self.layout!r}")
        if self.uniform and (self.bandwidth != 5 or self.layout != "shared"):
            raise ValueError("uniform is a shared-penta concept "
                             "(cuPentUniformBatch)")
        if self.transposed and self.layout == "batch":
            raise ValueError(
                "no transposed batch kernels: rolling the per-lane diagonals "
                "turns A^T into another batch system, so the forward batch "
                "kernels serve the adjoint (repro.solver.pallas)")
        if self.fused and not self.streamed:
            raise ValueError(
                "fused is a streamed concept: the resident kernels already "
                "run both passes in one pallas_call; fused=True fuses the "
                "STREAMED forward/backward pair onto one ascend/descend grid")

    # -- derived structure --------------------------------------------------

    @property
    def order(self) -> int:
        """Carry order of each sweep pass (1st/2nd-order recurrence)."""
        return 1 if self.bandwidth == 3 else 2

    @property
    def lhs_rows(self) -> int:
        """Rows of the stacked shared LHS block (0 for batch layout)."""
        if self.layout != "shared":
            return 0
        if self.bandwidth == 3:
            return 3
        return 4 if self.uniform else 5

    @property
    def n_coefs(self) -> int:
        """Per-lane coefficient arrays the fused factorisation produces
        (c_hat, or gamma+delta) — the batch kernels' scratch/spill."""
        return self.order if self.layout == "batch" else 0

    @property
    def carry_rows(self) -> int:
        """Rows of the streamed forward kernel's VMEM carry scratch."""
        if self.layout == "batch":
            # factorisation carries: (c_hat, d_hat) or
            # (gamma x2, delta x2, g x2) lags
            return 2 if self.order == 1 else 6
        return self.order

    @property
    def mode(self) -> str:
        if self.layout == "batch":
            return "batch"
        return "uniform" if self.uniform else "constant"

    @property
    def name(self) -> str:
        base = "thomas" if self.bandwidth == 3 else "penta"
        name = f"{base}_{self.mode}"
        if self.streamed:
            name += "_streamed"
        if self.fused:
            name += "_fused"
        if self.transposed:
            name += "_t"
        return name

    def passes(self) -> tuple:
        """(forward PassSpec, backward PassSpec) for this variant."""
        if self.layout == "batch":
            return None, _BATCH_BWD[self.order]
        return _PASS_TABLE[(self.bandwidth, self.uniform, self.transposed)]

    @property
    def scale_row(self) -> int:
        """Row index of the stored inverse diagonal in the stacked LHS —
        the ONLY row a pass's ``scale`` may legally point at (0 for batch
        layout, where the fused factorisation holds the inverse).

        Uniform stacks drop the eps row, so the inverse sits one row
        lower on the forward side ([beta, inv_alpha, gamma, delta]) but
        keeps row 2 on the transposed side ([delta, gamma, inv_alpha,
        beta] — the dropped row is at the other end of the band)."""
        if self.layout == "batch":
            return 0
        if self.bandwidth == 3:
            return 1
        if self.uniform and not self.transposed:
            return 1
        return 2

    @property
    def resident_name(self) -> str:
        """Name of the VMEM-resident sibling (self when not streamed)."""
        return dataclasses.replace(self, streamed=False, fused=False).name

    @property
    def unfused_name(self) -> str:
        """Name of the two-call streamed sibling (self when not fused) —
        the spill target when the fused working set exceeds the budget."""
        return dataclasses.replace(self, fused=False).name

    def twin_name(self) -> str | None:
        """Name of the transposed twin spec (None for batch layout, whose
        adjoint reuses the forward kernels on rolled diagonals)."""
        if self.layout == "batch":
            return None
        return dataclasses.replace(self, transposed=not self.transposed).name

    def dummy_args(self, n: int, m: int, dtype=jnp.float32) -> tuple:
        """``(args, eps)`` zero-filled operands shaped for this spec's
        solver entry point — the introspection hook ``repro.analysis``
        uses to drive the kernel builders under abstract interpretation
        (no solve ever runs on them)."""
        if self.layout == "shared":
            args = (jnp.zeros((self.lhs_rows, n), dtype),
                    jnp.zeros((n, m), dtype))
            eps = jnp.zeros((1, 1), dtype) if self.uniform else None
            return args, eps
        return tuple(jnp.zeros((n, m), dtype)
                     for _ in range(self.bandwidth + 1)), None

    # -- derived accounting (no hand-kept tables) ---------------------------

    def storage_words(self, n: int, m: int) -> int:
        """HBM<->VMEM words one solve READS from stored operands — the
        factor/diagonals, the (streamed) RHS, and the eps parameter.
        These are the words a ``storage_dtype`` override (bf16 in HBM,
        fp32 in-kernel) shrinks; everything written moves at the compute
        dtype and is counted by ``compute_words``."""
        if self.layout == "batch":
            # diagonals + rhs in.
            return (self.bandwidth + 1) * n * m
        # rhs in; the two-call streamed pair re-reads the LHS for its
        # backward kernel, the fused/resident variants read it once.
        lhs_passes = 1 if (self.fused or not self.streamed) else 2
        eps = 1 if self.uniform else 0
        return n * m + lhs_passes * self.lhs_rows * n + eps

    def compute_words(self, n: int, m: int) -> int:
        """HBM<->VMEM words moved at the COMPUTE dtype (fp32-accumulated,
        regardless of ``storage_dtype``): the final x, plus — for the
        two-call streamed pair only — the intermediate (and, for batch,
        the spilled factor coefficients) round-tripped through HBM between
        the forward and backward kernels.  Resident and fused variants
        keep d_hat/g in VMEM, so their only compute-dtype stream is x."""
        if not self.streamed or self.fused:
            return n * m
        if self.layout == "batch":
            # x out + fwd writes intermediate + n_coefs spills which the
            # bwd kernel reads back.
            return (1 + 2 * (1 + self.n_coefs)) * n * m
        # x out + the d_hat/g round trip.
        return 3 * n * m

    def traffic_words(self, n: int, m: int) -> int:
        """HBM<->VMEM words one solve of an (n, m) RHS moves — the roofline
        memory term the paper's speed-up rests on, derived from the spec's
        stream structure (passes x {operands in, results out, LHS rows})."""
        return self.storage_words(n, m) + self.compute_words(n, m)

    def traffic_bytes(self, n: int, m: int, dtype=jnp.float32,
                      storage_dtype=None) -> int:
        """Bytes moved, itemized PER OPERAND CLASS: stored operands move at
        ``storage_dtype`` (defaults to ``dtype``), intermediates at the
        compute dtype — so the bf16-storage path halves the storage term
        while the spilled intermediates (if any) stay full width."""
        s_item = jnp.dtype(storage_dtype or dtype).itemsize
        c_item = jnp.dtype(dtype).itemsize
        return (self.storage_words(n, m) * s_item
                + self.compute_words(n, m) * c_item)

    def sharded_traffic_words(self, n: int, m: int, n_shards: int) -> int:
        """PER-DEVICE HBM<->VMEM words when the M axis is sharded over
        ``n_shards`` devices and each device runs this spec's kernels on
        its local slice (the sharded x streamed composition).

        The solve needs no collectives, so the per-device traffic is just
        ``traffic_words`` of the local lane count — for the shared layout
        the ``lhs_rows * n`` LHS stream does NOT shrink with the mesh
        (one replicated factor copy per device, the paper's storage idea
        applied per device), while the RHS terms divide by the shard
        count (up to mesh padding)."""
        from .common import shard_lanes
        return self.traffic_words(n, shard_lanes(m, n_shards))

    def vmem_counts(self) -> tuple:
        """(n_rhs_blocks, n_lhs_vecs, n_carry_rows) for the VMEM budget
        checks (``common.check_vmem`` / ``check_vmem_streamed`` /
        ``check_vmem_fused``).  For the streamed batch pair this is the
        FORWARD kernel's (larger) chunk working set: diagonals + rhs in,
        intermediate + spilled coefs out.  The fused variants hold the
        intermediate/spills in full-N VMEM scratch instead (counted
        separately by ``sweep_scratch``), so their chunk-block count drops
        back to operands in + x out."""
        if self.layout == "shared":
            return 2, self.lhs_rows, self.order
        if self.fused:
            return self.bandwidth + 2, 0, self.carry_rows
        blocks = self.bandwidth + 1 + 1 + self.n_coefs
        return blocks, 0, self.carry_rows

    def sweep_scratch(self) -> int:
        """Full-length (N, BLOCK_M) VMEM scratch arrays a fused kernel
        keeps resident across its ascend/descend walk — the intermediate
        d_hat/g (plus, for batch layout, the factor coefficients) that the
        two-call pair would spill to HBM.  0 for every non-fused spec."""
        if not self.fused:
            return 0
        return 1 + self.n_coefs

    @property
    def num_pallas_calls(self) -> int:
        """``pl.pallas_call`` count one solve of this spec emits — the
        accounting invariant the capture layer cross-checks.  Streamed
        sweeps are a forward/backward kernel PAIR unless fused; resident
        and fused sweeps run both passes in one kernel."""
        return 2 if (self.streamed and not self.fused) else 1


@dataclasses.dataclass(frozen=True)
class RecurrenceSpec:
    """Declarative description of one gated-linear-recurrence variant.

    The sweep machine's second spec family (DESIGN.md §4): same generic
    pass body (``_solve_pass``), same streamed split-N grid plumbing, same
    registry/accounting/speclint contracts as ``SweepSpec`` — but the
    multiplicative coefficients arrive as per-token (N, M) gate operands
    (one per carry lag) instead of a shared stacked LHS, and a solve is a
    SINGLE pass (a recurrence has no back-substitution partner).

    ``reverse`` runs the recurrence from i = N-1 down to 0
    (h_i = p_i h_{i+1} + q_i) — the suffix-scan shape, NOT an adjoint of
    the forward variant (the adjoint additionally shifts the gates, which
    the dispatcher ``core.recurrence`` does on the host).
    """

    order: int                # 1 | 2 carry lags
    reverse: bool = False     # walk the sweep axis descending
    streamed: bool = False    # HBM-streamed split-N vs VMEM-resident

    #: a single-pass recurrence has nothing to fuse — class attribute so
    #: the analysis layers can branch on ``spec.fused`` uniformly.
    fused = False

    def __post_init__(self):
        if self.order not in (1, 2):
            raise ValueError(f"recurrence order must be 1 or 2, "
                             f"got {self.order}")

    # -- derived structure --------------------------------------------------

    @property
    def layout(self) -> str:
        return "recurrence"

    @property
    def lhs_rows(self) -> int:
        return 0              # no shared stacked LHS — gates are operands

    @property
    def carry_rows(self) -> int:
        return self.order

    @property
    def mode(self) -> str:
        return "recurrence"

    @property
    def name(self) -> str:
        name = f"recur{self.order}"
        if self.streamed:
            name += "_streamed"
        if self.reverse:
            name += "_rev"
        return name

    def passes(self) -> tuple:
        """``(pass,)`` — a recurrence is ONE sweep pass (no partner)."""
        return (_RECUR_TABLE[self.order],)

    @property
    def resident_name(self) -> str:
        return dataclasses.replace(self, streamed=False).name

    def twin_name(self) -> str:
        """Name of the reversed twin (same pass table, mirrored walk)."""
        return dataclasses.replace(self, reverse=not self.reverse).name

    def dummy_args(self, n: int, m: int, dtype=jnp.float32) -> tuple:
        """``(args, eps)`` zero-filled operands shaped for
        ``recurrence_solver``: ``order`` gate arrays then the additive
        operand, all (n, m).  ``eps`` is always None (no uniform mode)."""
        return tuple(jnp.zeros((n, m), dtype)
                     for _ in range(self.order + 1)), None

    # -- derived accounting (no hand-kept tables) ---------------------------

    def storage_words(self, n: int, m: int) -> int:
        """Words read from stored operands: ``order`` gate arrays + the
        additive operand (no shared LHS, no eps)."""
        return (self.order + 1) * n * m

    def compute_words(self, n: int, m: int) -> int:
        """Words moved at the compute dtype: h out (a single pass has no
        inter-kernel intermediate to round-trip)."""
        return n * m

    def traffic_words(self, n: int, m: int) -> int:
        """HBM<->VMEM words one solve moves: ``order`` gate operands + the
        additive operand in, h out — identical for resident and streamed
        (a single pass streams every chunk exactly once; nothing is
        revisited, unlike the two-pass sweeps)."""
        return self.storage_words(n, m) + self.compute_words(n, m)

    def traffic_bytes(self, n: int, m: int, dtype=jnp.float32,
                      storage_dtype=None) -> int:
        s_item = jnp.dtype(storage_dtype or dtype).itemsize
        c_item = jnp.dtype(dtype).itemsize
        return (self.storage_words(n, m) * s_item
                + self.compute_words(n, m) * c_item)

    def sharded_traffic_words(self, n: int, m: int, n_shards: int) -> int:
        """PER-DEVICE words with M sharded: every stream is lane-tiled
        (no replicated shared-LHS term), so everything divides by the
        shard count (up to mesh padding)."""
        from .common import shard_lanes
        return self.traffic_words(n, shard_lanes(m, n_shards))

    def vmem_counts(self) -> tuple:
        """(n_rhs_blocks, n_lhs_vecs, n_carry_rows): gates + operand + h
        are all lane-tiled blocks; no shared LHS vectors; ``order`` carry
        rows thread the streamed chunks."""
        return self.order + 2, 0, self.order

    def sweep_scratch(self) -> int:
        """No fused variant, so never any full-N VMEM sweep scratch."""
        return 0

    @property
    def num_pallas_calls(self) -> int:
        """Always 1: a recurrence solve is a single pass, so even the
        streamed variant is ONE kernel walking its chunks sequentially."""
        return 1


def _all_specs() -> tuple:
    specs = []
    for bw in (3, 5):
        for transposed in (False, True):
            for streamed, fused in ((False, False), (True, False),
                                    (True, True)):
                specs.append(SweepSpec(bw, "shared", transposed=transposed,
                                       streamed=streamed, fused=fused))
                if bw == 5:
                    specs.append(SweepSpec(bw, "shared", transposed=transposed,
                                           streamed=streamed, fused=fused,
                                           uniform=True))
        for streamed, fused in ((False, False), (True, False), (True, True)):
            specs.append(SweepSpec(bw, "batch", streamed=streamed,
                                   fused=fused))
    for order in (1, 2):
        for reverse in (False, True):
            for streamed in (False, True):
                specs.append(RecurrenceSpec(order, reverse=reverse,
                                            streamed=streamed))
    return tuple(specs)


#: Every variant the engine generates, by name — the single source the
#: dispatcher, the traffic model, and the CI parity matrix all enumerate.
REGISTRY: dict = {s.name: s for s in _all_specs()}


def find_spec(bandwidth: int, mode: str, *, streamed: bool = False,
              transposed: bool = False, fused: bool = False) -> SweepSpec:
    """Look up the spec serving (bandwidth, storage mode) — the tridiag
    ``uniform`` mode shares the constant kernel (no eps vector to drop).

    Unknown combinations raise ``ValueError`` naming the valid choices
    (never a bare ``KeyError`` leaking the internal registry key)."""
    if bandwidth not in (3, 5):
        raise ValueError(
            f"no sweep kernels for bandwidth={bandwidth!r}; the engine "
            f"serves bandwidth 3 (tridiagonal) and 5 (pentadiagonal)")
    if mode not in ("constant", "uniform", "batch"):
        raise ValueError(
            f"unknown storage mode {mode!r}; valid modes are 'constant' "
            f"(one shared LHS), 'uniform' (all-equal diagonals) and "
            f"'batch' (per-system LHS copies)")
    if mode == "batch" and transposed:
        raise ValueError(
            "no transposed batch kernels are registered: the adjoint of a "
            "batch solve rolls the per-lane diagonals into another batch "
            "system and reuses the FORWARD batch kernels "
            "(repro.solver.pallas.transpose_solve_stored) — call with "
            "transposed=False on the rolled diagonals")
    if fused and not streamed:
        raise ValueError(
            "fused=True is a streamed refinement (one ascend/descend "
            "pallas_call instead of the forward/backward pair); the "
            "resident kernels are already single-call — pass streamed=True "
            "or drop fused")
    if bandwidth == 3 and mode == "uniform":
        mode = "constant"
    base = "thomas" if bandwidth == 3 else "penta"
    name = f"{base}_{mode}"
    if streamed:
        name += "_streamed"
    if fused:
        name += "_fused"
    if transposed:
        name += "_t"
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"no registered sweep kernel named {name!r} for "
            f"bandwidth={bandwidth}, mode={mode!r}, streamed={streamed}, "
            f"transposed={transposed}; registered variants: "
            f"{sorted(REGISTRY)}") from None


def find_recurrence_spec(order: int, *, reverse: bool = False,
                         streamed: bool = False) -> RecurrenceSpec:
    """Look up the registered gated-recurrence spec for ``order`` with
    the requested walk direction and residency.  Unknown orders raise
    ``ValueError`` naming the valid choices."""
    if order not in (1, 2):
        raise ValueError(
            f"no recurrence kernels for order={order!r}; the engine "
            f"serves order 1 (h = p*h' + q) and order 2 "
            f"(h = s*h' + t*h'' + u)")
    name = RecurrenceSpec(order, reverse=reverse, streamed=streamed).name
    return REGISTRY[name]


def pass_table() -> dict:
    """A copy of the shared-layout pass tables, keyed by
    ``(bandwidth, uniform, transposed)`` — the introspection hook
    ``repro.analysis.speccheck`` audits (a copy: mutating it cannot
    corrupt the engine)."""
    return dict(_PASS_TABLE)


def batch_backward_table() -> dict:
    """A copy of the batch-layout back-substitution table, keyed by carry
    order — the fused forward factorisation has no PassSpec (its
    coefficient algebra lives in ``_factor_pass``)."""
    return dict(_BATCH_BWD)


def recurrence_table() -> dict:
    """A copy of the gated-recurrence pass table, keyed by carry order —
    the introspection hook ``repro.analysis.speccheck`` audits."""
    return dict(_RECUR_TABLE)


def traffic_table(bandwidth: int, n: int, m: int, dtype=jnp.float32) -> dict:
    """{variant_key: bytes} for every registered sweep spec of
    ``bandwidth`` — keys are the spec names minus the thomas_/penta_
    prefix (``constant``, ``constant_streamed_t``, ``batch_streamed``, …).
    Recurrence specs key their own family; see ``recurrence_traffic_table``."""
    prefix = ("thomas_" if bandwidth == 3 else "penta_")
    return {s.name[len(prefix):]: s.traffic_bytes(n, m, dtype)
            for s in REGISTRY.values()
            if isinstance(s, SweepSpec) and s.bandwidth == bandwidth}


def recurrence_traffic_table(n: int, m: int, dtype=jnp.float32) -> dict:
    """{spec_name: bytes} for every registered recurrence spec."""
    return {s.name: s.traffic_bytes(n, m, dtype)
            for s in REGISTRY.values() if isinstance(s, RecurrenceSpec)}


# ---------------------------------------------------------------------------
# Generic pass bodies
# ---------------------------------------------------------------------------

def _shared_coeff(lhs_ref, eps_ref):
    """Coefficient accessor for the shared layout: scalar per sweep row,
    broadcast across the lane tile (the paper's broadcast-hit LHS copy)."""
    def at(src, i):
        if src == EPS_PARAM:
            return eps_ref[0, 0]
        return scalar(lhs_ref, src, i)
    return at


def _shift(off):
    """Index shifter: identity for the (static) zero offset so non-fused
    traces stay instruction-identical; otherwise adds the (possibly
    traced) base row of a fused kernel's full-N VMEM scratch."""
    if isinstance(off, int) and off == 0:
        return lambda i: i
    return lambda i: off + i


def _lane_coeff(refs, off=0):
    """Coefficient accessor for the batch layout: a (BLOCK_M,) vector per
    sweep row, read from per-lane (N, BLOCK_M) refs.  ``off`` rebases the
    row index when the refs are a fused kernel's full-N scratch but the
    pass walks one BLOCK_N chunk of it."""
    at_row = _shift(off)

    def at(src, i):
        return row(refs[src], at_row(i), refs[src].shape[1])
    return at


def _solve_pass(coeff_at, in_ref, out_ref, init, *, pspec: PassSpec,
                order: int, length: int, reverse: bool, unroll: int,
                in_off=0, out_off=0):
    """Run one sweep pass; returns the final carry tuple.

    ``init`` is the carry tuple entering the pass (zeros, or the VMEM
    scratch rows threading a streamed sweep across N-chunks).  ``in_ref``
    and ``out_ref`` may alias (the resident kernels back-substitute in
    place over the intermediate they just wrote).  ``in_off``/``out_off``
    rebase the row index into refs that are LONGER than the pass (a fused
    kernel's full-N intermediate scratch vs its BLOCK_N chunk walk);
    coefficient rows are always chunk-local (``coeff_at`` carries its own
    base when needed)."""
    m = in_ref.shape[1]
    in_at, out_at = _shift(in_off), _shift(out_off)

    def body(t, carries):
        i = length - 1 - t if reverse else t
        acc = row(in_ref, in_at(i), m)
        for src, lag in pspec.terms:
            acc = acc - coeff_at(src, i) * carries[lag - 1]
        if pspec.scale is not None:
            acc = acc * coeff_at(pspec.scale, i)
        store_row(out_ref, out_at(i), acc)
        return (acc,) + carries[:order - 1]

    return jax.lax.fori_loop(0, length, body, tuple(init), unroll=unroll)


def _factor_pass(diag_at, rhs_ref, coef_store, out_ref, init, *, order: int,
                 length: int, unroll: int, out_off=0):
    """Fused factorisation + forward sweep (batch layout: cuThomasBatch /
    cuPentBatch semantics — the per-lane LHS is re-factored every solve).

    Zero-initialised carries make row 0 (and row 1 for penta) fall out of
    the general step: ``a_0``/``b_0`` only ever multiply zero carries, so
    no boundary special-casing — which is also what makes the streamed
    chunking and the identity sweep-padding exact."""
    m = rhs_ref.shape[1]
    out_at = _shift(out_off)

    if order == 1:
        def body(i, carry):
            chat_p, dh_p = carry
            a_i = diag_at(0, i)
            inv = 1.0 / (diag_at(1, i) - a_i * chat_p)
            chat = diag_at(2, i) * inv
            coef_store(0, i, chat)
            dh = (row(rhs_ref, i, m) - a_i * dh_p) * inv
            store_row(out_ref, out_at(i), dh)
            return chat, dh
    else:
        def body(i, carry):
            g1, g2, dl1, dl2, gg1, gg2 = carry
            a_i = diag_at(0, i)
            beta_i = diag_at(1, i) - a_i * g2
            alpha_i = diag_at(2, i) - a_i * dl2 - beta_i * g1
            inv = 1.0 / alpha_i
            gamma_i = (diag_at(3, i) - beta_i * dl1) * inv
            delta_i = diag_at(4, i) * inv
            coef_store(0, i, gamma_i)
            coef_store(1, i, delta_i)
            g_i = (row(rhs_ref, i, m) - a_i * gg2 - beta_i * gg1) * inv
            store_row(out_ref, out_at(i), g_i)
            return gamma_i, g1, delta_i, dl1, g_i, gg1

    return jax.lax.fori_loop(0, length, body, tuple(init), unroll=unroll)


def _compute_dtype(dtype):
    """In-kernel accumulation dtype: carries, intermediates, and the final
    x stay at least fp32 even when the stored operands arrive bf16 (the
    mixed-precision storage path — cast up on load, never accumulate in
    bf16).  Identity for fp32/fp64 inputs, preserving bit-exactness."""
    return jnp.promote_types(dtype, jnp.float32)


def _compiler_params(prefetch: bool, interpret: bool) -> dict:
    """Mosaic knobs for the streamed/fused 2-D grids.  ``prefetch=True``
    marks the lane axis ``parallel`` (the N-chunk axis stays ``arbitrary``
    — its carry scratch is sequential), letting the pipeline stage the
    next chunk's operand DMA into the second VMEM buffer while the
    current chunk computes.  Interpret mode (CPU CI) takes no compiler
    params at all — the interpreter executes grid steps serially, so this
    is also the interpret-safe fallback."""
    if interpret:
        return {}
    sem = ("parallel", "arbitrary") if prefetch else ("arbitrary",
                                                      "arbitrary")
    return {"compiler_params":
            pltpu.TPUCompilerParams(dimension_semantics=sem)}


# ---------------------------------------------------------------------------
# Shared-layout kernels (one factored LHS, broadcast to every lane)
# ---------------------------------------------------------------------------

def _shared_resident_kernel(*refs, spec: SweepSpec, n: int, unroll: int):
    """Both passes in one kernel; the output block doubles as intermediate
    storage (forward writes d_hat/g, backward overwrites with x)."""
    if spec.uniform:
        eps_ref, lhs_ref, in_ref, x_ref = refs
    else:
        (lhs_ref, in_ref, x_ref), eps_ref = refs, None
    fwd, bwd = spec.passes()
    at = _shared_coeff(lhs_ref, eps_ref)
    m = in_ref.shape[1]
    zeros = (jnp.zeros((m,), x_ref.dtype),) * spec.order
    _solve_pass(at, in_ref, x_ref, zeros, pspec=fwd, order=spec.order,
                length=n, reverse=False, unroll=unroll)
    _solve_pass(at, x_ref, x_ref, zeros, pspec=bwd, order=spec.order,
                length=n, reverse=True, unroll=unroll)


def _shared_streamed_kernel(*refs, pspec: PassSpec, order: int, block_n: int,
                            reverse: bool, uniform: bool, unroll: int):
    """One pass over one (BLOCK_N, BLOCK_M) chunk; the carry scratch
    threads the sweep state across the sequential N-chunk grid steps."""
    if uniform:
        eps_ref, lhs_ref, in_ref, out_ref, carry_ref = refs
    else:
        (lhs_ref, in_ref, out_ref, carry_ref), eps_ref = refs, None
    m = in_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))
    init = tuple(row(carry_ref, j, m) for j in range(order))
    final = _solve_pass(_shared_coeff(lhs_ref, eps_ref), in_ref, out_ref,
                        init, pspec=pspec, order=order, length=block_n,
                        reverse=reverse, unroll=unroll)
    for j in range(order):
        store_row(carry_ref, j, final[j])


def _shared_fused_kernel(*refs, spec: SweepSpec, block_n: int, num_n: int,
                         unroll: int):
    """Both streamed passes in ONE kernel on the ascend/descend grid:
    steps k < num_n run the forward pass over ascending chunks, writing
    the intermediate (d_hat / g) into the full-N VMEM scratch ``mid_ref``;
    steps k >= num_n run back substitution over descending chunks, reading
    ``mid_ref`` back — the HBM round trip of the two-call pair, eliminated.
    The carry scratch resets at k == 0 AND k == num_n (``k % num_n``): each
    phase starts from the zero-carry boundary protocol."""
    if spec.uniform:
        eps_ref, lhs_ref, in_ref, x_ref, mid_ref, carry_ref = refs
    else:
        (lhs_ref, in_ref, x_ref, mid_ref, carry_ref), eps_ref = refs, None
    fwd, bwd = spec.passes()
    at = _shared_coeff(lhs_ref, eps_ref)
    m = in_ref.shape[1]
    k = pl.program_id(1)
    reset_carry(carry_ref, k % num_n)
    init = tuple(row(carry_ref, j, m) for j in range(spec.order))
    # Base rows into the full-N scratch: the chunk this step ascends into /
    # descends from (clamped like the index maps, so the not-taken branch
    # never addresses out of range).
    off = _imin(k, num_n - 1) * block_n
    doff = _imin(2 * num_n - 1 - k, num_n - 1) * block_n

    @pl.when(k < num_n)
    def _ascend():
        final = _solve_pass(at, in_ref, mid_ref, init, pspec=fwd,
                            order=spec.order, length=block_n, reverse=False,
                            unroll=unroll, out_off=off)
        for j in range(spec.order):
            store_row(carry_ref, j, final[j])

    @pl.when(k >= num_n)
    def _descend():
        final = _solve_pass(at, mid_ref, x_ref, init, pspec=bwd,
                            order=spec.order, length=block_n, reverse=True,
                            unroll=unroll, in_off=doff)
        for j in range(spec.order):
            store_row(carry_ref, j, final[j])


@functools.lru_cache(maxsize=None)
def shared_solver(spec: SweepSpec):
    """Compile ``spec`` (shared layout) into its jitted pallas entry point:
    ``solver(lhs, rhs, *, block_m, [block_n,] unroll, interpret, eps)``.

    ``lhs`` is the (rows, N) stack of ``repro.kernels.ops.stack_*_lhs``
    (pre-shifted for transposed specs); ``eps`` is the (1, 1) uniform
    parameter operand.  Callers pad: M % block_m == 0, and for streamed
    specs N % block_n == 0."""
    assert spec.layout == "shared"

    if not spec.streamed:
        @functools.partial(jax.jit,
                           static_argnames=("block_m", "unroll", "interpret"))
        def solver(lhs, rhs, *, block_m=128, unroll=1, interpret=True,
                   eps=None):
            n, m = rhs.shape
            cdt = _compute_dtype(rhs.dtype)
            in_specs = [pl.BlockSpec((spec.lhs_rows, n), lambda j: (0, 0)),
                        _col_spec(n, block_m)]
            args = [lhs, rhs]
            if spec.uniform:
                in_specs.insert(0, pl.BlockSpec((1, 1), lambda j: (0, 0)))
                args.insert(0, eps)
            return pl.pallas_call(
                functools.partial(_shared_resident_kernel, spec=spec, n=n,
                                  unroll=unroll),
                grid=(m // block_m,),
                in_specs=in_specs,
                out_specs=_col_spec(n, block_m),
                out_shape=jax.ShapeDtypeStruct((n, m), cdt),
                interpret=interpret,
            )(*args)
        return solver

    if spec.fused:
        @functools.partial(jax.jit,
                           static_argnames=("block_m", "block_n", "unroll",
                                            "interpret", "prefetch"))
        def solver(lhs, rhs, *, block_m=128, block_n=512, unroll=1,
                   interpret=True, eps=None, prefetch=False):
            n, m = rhs.shape
            cdt = _compute_dtype(rhs.dtype)
            num_n = n // block_n
            in_specs = [fused_lhs_spec(spec.lhs_rows, block_n, num_n),
                        fused_chunk_spec(block_n, block_m, num_n,
                                         phase="ascend")]
            args = [lhs, rhs]
            if spec.uniform:
                in_specs.insert(0, pl.BlockSpec((1, 1), lambda j, k: (0, 0)))
                args.insert(0, eps)
            return pl.pallas_call(
                functools.partial(_shared_fused_kernel, spec=spec,
                                  block_n=block_n, num_n=num_n,
                                  unroll=unroll),
                grid=(m // block_m, 2 * num_n),
                in_specs=in_specs,
                out_specs=fused_chunk_spec(block_n, block_m, num_n,
                                           phase="descend"),
                out_shape=jax.ShapeDtypeStruct((n, m), cdt),
                scratch_shapes=[pltpu.VMEM((n, block_m), cdt),
                                pltpu.VMEM((spec.order, block_m), cdt)],
                interpret=interpret,
                **_compiler_params(prefetch, interpret),
            )(*args)
        return solver

    @functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                                 "unroll", "interpret",
                                                 "prefetch"))
    def solver(lhs, rhs, *, block_m=128, block_n=512, unroll=1,
               interpret=True, eps=None, prefetch=False):
        n, m = rhs.shape
        cdt = _compute_dtype(rhs.dtype)
        num_n = n // block_n
        grid = (m // block_m, num_n)
        carry = [pltpu.VMEM((spec.order, block_m), cdt)]
        fwd, bwd = spec.passes()

        def one_pass(pspec, reverse, operand):
            in_specs = [chunk_lhs_spec(spec.lhs_rows, block_n, num_n,
                                       reverse=reverse),
                        chunk_spec(block_n, block_m, num_n, reverse=reverse)]
            args = [lhs, operand]
            if spec.uniform:
                in_specs.insert(0, pl.BlockSpec((1, 1), lambda j, k: (0, 0)))
                args.insert(0, eps)
            return pl.pallas_call(
                functools.partial(_shared_streamed_kernel, pspec=pspec,
                                  order=spec.order, block_n=block_n,
                                  reverse=reverse, uniform=spec.uniform,
                                  unroll=unroll),
                grid=grid,
                in_specs=in_specs,
                out_specs=chunk_spec(block_n, block_m, num_n, reverse=reverse),
                out_shape=jax.ShapeDtypeStruct((n, m), cdt),
                scratch_shapes=carry,
                interpret=interpret,
                **_compiler_params(prefetch, interpret),
            )(*args)

        mid = one_pass(fwd, False, rhs)           # ascending: d_hat / g
        return one_pass(bwd, True, mid)           # descending: x
    return solver


# ---------------------------------------------------------------------------
# Batch-layout kernels (per-lane LHS, factorisation fused into the solve)
# ---------------------------------------------------------------------------

def _batch_resident_kernel(*refs, spec: SweepSpec, n: int, unroll: int):
    nd = spec.bandwidth
    diag_refs, rhs_ref, x_ref = refs[:nd], refs[nd], refs[nd + 1]
    coef_refs = refs[nd + 2:]                     # VMEM scratch
    m = rhs_ref.shape[1]
    zeros = jnp.zeros((m,), x_ref.dtype)
    _factor_pass(_lane_coeff(diag_refs), rhs_ref,
                 lambda r, i, v: store_row(coef_refs[r], i, v),
                 x_ref, (zeros,) * spec.carry_rows, order=spec.order,
                 length=n, unroll=unroll)
    _, bwd = spec.passes()
    _solve_pass(_lane_coeff(coef_refs), x_ref, x_ref, (zeros,) * spec.order,
                pspec=bwd, order=spec.order, length=n, reverse=True,
                unroll=unroll)


def _batch_streamed_fwd_kernel(*refs, spec: SweepSpec, block_n: int,
                               unroll: int):
    """Fused factorisation over ascending chunks; the intermediate AND the
    factor coefficients (c_hat / gamma+delta) spill to HBM for the
    backward kernel (DESIGN.md §2.2's scratch-spill layout)."""
    nd = spec.bandwidth
    diag_refs, rhs_ref = refs[:nd], refs[nd]
    out_ref = refs[nd + 1]
    coef_refs = refs[nd + 2:nd + 2 + spec.n_coefs]   # HBM-backed outputs
    carry_ref = refs[-1]
    m = rhs_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))
    init = tuple(row(carry_ref, j, m) for j in range(spec.carry_rows))
    final = _factor_pass(_lane_coeff(diag_refs), rhs_ref,
                         lambda r, i, v: store_row(coef_refs[r], i, v),
                         out_ref, init, order=spec.order, length=block_n,
                         unroll=unroll)
    for j in range(spec.carry_rows):
        store_row(carry_ref, j, final[j])


def _batch_streamed_bwd_kernel(*refs, spec: SweepSpec, block_n: int,
                               unroll: int):
    """Back substitution over descending chunks, reading the spilled
    coefficients back from HBM."""
    coef_refs = refs[:spec.n_coefs]
    in_ref, x_ref, carry_ref = refs[spec.n_coefs], refs[spec.n_coefs + 1], \
        refs[-1]
    m = in_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))
    _, bwd = spec.passes()
    init = tuple(row(carry_ref, j, m) for j in range(spec.order))
    final = _solve_pass(_lane_coeff(coef_refs), in_ref, x_ref, init,
                        pspec=bwd, order=spec.order, length=block_n,
                        reverse=True, unroll=unroll)
    for j in range(spec.order):
        store_row(carry_ref, j, final[j])


def _batch_fused_kernel(*refs, spec: SweepSpec, block_n: int, num_n: int,
                        unroll: int):
    """Fused factorisation + back substitution in ONE kernel on the
    ascend/descend grid: the intermediate AND the factor coefficients
    (c_hat / gamma+delta) live in full-N VMEM scratch instead of spilling
    to HBM between the two-call pair's kernels.  The carry scratch resets
    at k == 0 AND k == num_n (``k % num_n``) — the descend phase's
    (smaller) back-substitution carry reuses the leading rows."""
    nd = spec.bandwidth
    diag_refs, rhs_ref, x_ref = refs[:nd], refs[nd], refs[nd + 1]
    mid_ref = refs[nd + 2]
    coef_refs = refs[nd + 3:nd + 3 + spec.n_coefs]   # full-N VMEM scratch
    carry_ref = refs[-1]
    m = rhs_ref.shape[1]
    k = pl.program_id(1)
    reset_carry(carry_ref, k % num_n)
    off = _imin(k, num_n - 1) * block_n
    doff = _imin(2 * num_n - 1 - k, num_n - 1) * block_n

    @pl.when(k < num_n)
    def _ascend():
        init = tuple(row(carry_ref, j, m) for j in range(spec.carry_rows))
        final = _factor_pass(
            _lane_coeff(diag_refs), rhs_ref,
            lambda r, i, v: store_row(coef_refs[r], off + i, v),
            mid_ref, init, order=spec.order, length=block_n,
            unroll=unroll, out_off=off)
        for j in range(spec.carry_rows):
            store_row(carry_ref, j, final[j])

    @pl.when(k >= num_n)
    def _descend():
        _, bwd = spec.passes()
        init = tuple(row(carry_ref, j, m) for j in range(spec.order))
        final = _solve_pass(_lane_coeff(coef_refs, off=doff), mid_ref, x_ref,
                            init, pspec=bwd, order=spec.order,
                            length=block_n, reverse=True, unroll=unroll,
                            in_off=doff)
        for j in range(spec.order):
            store_row(carry_ref, j, final[j])


@functools.lru_cache(maxsize=None)
def batch_solver(spec: SweepSpec):
    """Compile ``spec`` (batch layout) into its jitted pallas entry point:
    ``solver(*diagonals, rhs, *, block_m, [block_n,] unroll, interpret)``.

    Callers pad lanes (identity main diagonal) and, for streamed specs,
    the sweep axis (identity main diagonal there too — the fused
    factorisation divides in-kernel, see ``common.pad_sweep``)."""
    assert spec.layout == "batch"

    if not spec.streamed:
        @functools.partial(jax.jit,
                           static_argnames=("block_m", "unroll", "interpret"))
        def solver(*args, block_m=128, unroll=1, interpret=True):
            n, m = args[-1].shape
            cdt = _compute_dtype(args[-1].dtype)
            sp = _col_spec(n, block_m)
            return pl.pallas_call(
                functools.partial(_batch_resident_kernel, spec=spec, n=n,
                                  unroll=unroll),
                grid=(m // block_m,),
                in_specs=[sp] * (spec.bandwidth + 1),
                out_specs=sp,
                out_shape=jax.ShapeDtypeStruct((n, m), cdt),
                scratch_shapes=[pltpu.VMEM((n, block_m), cdt)
                                for _ in range(spec.n_coefs)],
                interpret=interpret,
            )(*args)
        return solver

    if spec.fused:
        @functools.partial(jax.jit,
                           static_argnames=("block_m", "block_n", "unroll",
                                            "interpret", "prefetch"))
        def solver(*args, block_m=128, block_n=512, unroll=1, interpret=True,
                   prefetch=False):
            n, m = args[-1].shape
            cdt = _compute_dtype(args[-1].dtype)
            num_n = n // block_n
            asc = fused_chunk_spec(block_n, block_m, num_n, phase="ascend")
            return pl.pallas_call(
                functools.partial(_batch_fused_kernel, spec=spec,
                                  block_n=block_n, num_n=num_n,
                                  unroll=unroll),
                grid=(m // block_m, 2 * num_n),
                in_specs=[asc] * (spec.bandwidth + 1),
                out_specs=fused_chunk_spec(block_n, block_m, num_n,
                                           phase="descend"),
                out_shape=jax.ShapeDtypeStruct((n, m), cdt),
                scratch_shapes=[pltpu.VMEM((n, block_m), cdt)
                                for _ in range(1 + spec.n_coefs)]
                               + [pltpu.VMEM((spec.carry_rows, block_m),
                                             cdt)],
                interpret=interpret,
                **_compiler_params(prefetch, interpret),
            )(*args)
        return solver

    @functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                                 "unroll", "interpret",
                                                 "prefetch"))
    def solver(*args, block_m=128, block_n=512, unroll=1, interpret=True,
               prefetch=False):
        n, m = args[-1].shape
        cdt = _compute_dtype(args[-1].dtype)
        num_n = n // block_n
        grid = (m // block_m, num_n)
        csp = chunk_spec(block_n, block_m, num_n)
        shape = jax.ShapeDtypeStruct((n, m), cdt)

        outs = pl.pallas_call(
            functools.partial(_batch_streamed_fwd_kernel, spec=spec,
                              block_n=block_n, unroll=unroll),
            grid=grid,
            in_specs=[csp] * (spec.bandwidth + 1),
            out_specs=[csp] * (1 + spec.n_coefs),
            out_shape=[shape] * (1 + spec.n_coefs),
            scratch_shapes=[pltpu.VMEM((spec.carry_rows, block_m), cdt)],
            interpret=interpret,
            **_compiler_params(prefetch, interpret),
        )(*args)
        mid, coefs = outs[0], outs[1:]

        rsp = chunk_spec(block_n, block_m, num_n, reverse=True)
        return pl.pallas_call(
            functools.partial(_batch_streamed_bwd_kernel, spec=spec,
                              block_n=block_n, unroll=unroll),
            grid=grid,
            in_specs=[rsp] * (spec.n_coefs + 1),
            out_specs=rsp,
            out_shape=shape,
            scratch_shapes=[pltpu.VMEM((spec.order, block_m), cdt)],
            interpret=interpret,
            **_compiler_params(prefetch, interpret),
        )(*coefs, mid)
    return solver


# ---------------------------------------------------------------------------
# Recurrence-layout kernels (per-token gate operands, single pass)
# ---------------------------------------------------------------------------

def _gate_coeff(refs):
    """Coefficient accessor for the recurrence layout: a (BLOCK_M,) gate
    vector per sweep row, read NEGATED from per-token (N, BLOCK_M) refs —
    ``_solve_pass`` subtracts its terms, a recurrence adds, and IEEE
    negation is exact, so ``q - (-p)*h`` is bitwise ``q + p*h``."""
    def at(src, i):
        return -row(refs[src], i, refs[src].shape[1])
    return at


def _recurrence_resident_kernel(*refs, spec: RecurrenceSpec, n: int,
                                unroll: int):
    """The whole recurrence in one kernel: a single ``_solve_pass`` over
    the resident (N, BLOCK_M) tiles, walked forward or reverse."""
    gate_refs, in_ref, out_ref = refs[:spec.order], refs[-2], refs[-1]
    (pspec,) = spec.passes()
    m = in_ref.shape[1]
    zeros = (jnp.zeros((m,), in_ref.dtype),) * spec.order
    _solve_pass(_gate_coeff(gate_refs), in_ref, out_ref, zeros, pspec=pspec,
                order=spec.order, length=n, reverse=spec.reverse,
                unroll=unroll)


def _recurrence_streamed_kernel(*refs, spec: RecurrenceSpec, block_n: int,
                                unroll: int):
    """One (BLOCK_N, BLOCK_M) chunk of the recurrence; the carry scratch
    threads h across the sequential N-chunk grid steps.  Reverse variants
    get DESCENDING chunks from their index maps, so inside the kernel the
    walk is the same reverse loop the resident kernel runs."""
    gate_refs = refs[:spec.order]
    in_ref, out_ref, carry_ref = refs[spec.order], refs[spec.order + 1], \
        refs[-1]
    (pspec,) = spec.passes()
    m = in_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))
    init = tuple(row(carry_ref, j, m) for j in range(spec.order))
    final = _solve_pass(_gate_coeff(gate_refs), in_ref, out_ref, init,
                        pspec=pspec, order=spec.order, length=block_n,
                        reverse=spec.reverse, unroll=unroll)
    for j in range(spec.order):
        store_row(carry_ref, j, final[j])


@functools.lru_cache(maxsize=None)
def recurrence_solver(spec: RecurrenceSpec):
    """Compile ``spec`` into its jitted pallas entry point:
    ``solver(*gates, q, *, block_m, [block_n,] unroll, interpret)``.

    ``gates`` are the ``order`` per-token (N, M) gate arrays (lag-1 first)
    and ``q`` the additive operand; all carries start at zero — nonzero
    h0 is folded into the boundary rows of ``q`` by the dispatcher
    (``repro.kernels.ops.recurrence``), which keeps the kernels on the
    same zero-carry protocol as every sweep kernel.  Callers pad:
    M % block_m == 0, and for streamed specs N % block_n == 0 (zero
    padding is exact: padded gate rows multiply a finite carry by 0)."""
    assert isinstance(spec, RecurrenceSpec)

    if not spec.streamed:
        @functools.partial(jax.jit,
                           static_argnames=("block_m", "unroll", "interpret"))
        def solver(*args, block_m=128, unroll=1, interpret=True):
            n, m = args[-1].shape
            sp = _col_spec(n, block_m)
            return pl.pallas_call(
                functools.partial(_recurrence_resident_kernel, spec=spec,
                                  n=n, unroll=unroll),
                grid=(m // block_m,),
                in_specs=[sp] * (spec.order + 1),
                out_specs=sp,
                out_shape=jax.ShapeDtypeStruct((n, m), args[-1].dtype),
                interpret=interpret,
            )(*args)
        return solver

    @functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                                 "unroll", "interpret"))
    def solver(*args, block_m=128, block_n=512, unroll=1, interpret=True):
        n, m = args[-1].shape
        num_n = n // block_n
        csp = chunk_spec(block_n, block_m, num_n, reverse=spec.reverse)
        return pl.pallas_call(
            functools.partial(_recurrence_streamed_kernel, spec=spec,
                              block_n=block_n, unroll=unroll),
            grid=(m // block_m, num_n),
            in_specs=[csp] * (spec.order + 1),
            out_specs=csp,
            out_shape=jax.ShapeDtypeStruct((n, m), args[-1].dtype),
            scratch_shapes=[pltpu.VMEM((spec.order, block_m),
                                       args[-1].dtype)],
            interpret=interpret,
        )(*args)
    return solver


def _col_spec(n: int, block_m: int):
    return pl.BlockSpec((n, block_m), lambda j: (0, j))
