"""Shared helpers for the Pallas TPU kernels.

All kernels in this package follow the paper's interleaved layout adapted to
TPU (DESIGN.md §2): the batch/system index M rides the 128-wide lane axis,
the unknown index N is the sequential sweep axis, and the shared LHS lives in
a single VMEM-resident block whose index_map is constant across the grid —
the TPU analogue of every CUDA warp broadcast-hitting one global LHS copy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM is ~16 MiB/core on recent TPUs; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # conservative per-kernel working set
LANE = 128          # TPU lane width — one system per lane (paper: one per thread)
SUBLANE = 8         # VREG sublane depth — sweep unroll granularity


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU containers validate via interpret."""
    return jax.default_backend() != "tpu"


def row(ref, i, width):
    """Load row i (dynamic) of a 2-D ref -> (width,) vector."""
    return ref[pl.ds(i, 1), :].reshape((width,))


def store_row(ref, i, val):
    ref[pl.ds(i, 1), :] = val.reshape((1,) + val.shape)


def scalar(ref, r, i):
    """Load element [r, i] (r static, i dynamic) of a 2-D ref -> scalar."""
    return ref[r:r + 1, pl.ds(i, 1)].reshape(())


def pad_lanes(x: jax.Array, block_m: int) -> tuple[jax.Array, int]:
    """Pad the minor (system) axis of an interleaved (N, M) batch to a
    multiple of the lane tile. Returns (padded, original_M)."""
    m = x.shape[-1]
    rem = (-m) % block_m
    if rem:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, rem)])
    return x, m


def vmem_working_set(n: int, block_m: int, n_rhs_blocks: int, n_lhs_vecs: int,
                     itemsize: int = 4) -> int:
    """Bytes of VMEM a solver grid step holds: RHS/out blocks + shared LHS."""
    return (n_rhs_blocks * n * block_m + n_lhs_vecs * n) * itemsize


def check_vmem(n: int, block_m: int, n_rhs_blocks: int, n_lhs_vecs: int,
               itemsize: int = 4) -> None:
    ws = vmem_working_set(n, block_m, n_rhs_blocks, n_lhs_vecs,
                          itemsize=itemsize)
    if ws > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"solver working set {ws/2**20:.1f} MiB exceeds VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB): N={n}, BLOCK_M={block_m}. "
            f"Reduce block_m or split N (HBM-streamed variant).")
