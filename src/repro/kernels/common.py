"""Shared helpers for the Pallas TPU kernels.

All kernels in this package follow the paper's interleaved layout adapted to
TPU (DESIGN.md §2): the batch/system index M rides the 128-wide lane axis,
the unknown index N is the sequential sweep axis, and the shared LHS lives in
a single VMEM-resident block whose index_map is constant across the grid —
the TPU analogue of every CUDA warp broadcast-hitting one global LHS copy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM is ~16 MiB/core on recent TPUs; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # conservative per-kernel working set
LANE = 128          # TPU lane width — one system per lane (paper: one per thread)
SUBLANE = 8         # VREG sublane depth — sweep unroll granularity


def default_interpret() -> bool:
    """Pallas kernels target TPU; on CPU containers validate via interpret."""
    return jax.default_backend() != "tpu"


def canonical_storage_dtype(storage_dtype):
    """Normalise a user-facing ``storage_dtype`` knob to a jnp dtype.

    ``None`` means "store at the operand dtype" (no mixed precision) and
    passes through.  The short alias ``"bf16"`` (and ``"bfloat16"``) maps
    to ``jnp.bfloat16`` — the storage precision of the mixed path: stored
    factor / diagonals / RHS live at this dtype in HBM, all carries and
    accumulation stay at least fp32 in-kernel.  Non-floating dtypes are
    rejected (integer storage would silently quantise the factor)."""
    if storage_dtype is None:
        return None
    if storage_dtype in ("bf16", "bfloat16"):
        return jnp.dtype(jnp.bfloat16)
    dt = jnp.dtype(storage_dtype)
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(
            f"storage_dtype must be a floating dtype (or 'bf16'), "
            f"got {storage_dtype!r}")
    return dt


def row(ref, i, width):
    """Load row i (dynamic) of a 2-D ref -> (width,) vector."""
    return ref[pl.ds(i, 1), :].reshape((width,))


def store_row(ref, i, val):
    ref[pl.ds(i, 1), :] = val.reshape((1,) + val.shape)


def scalar(ref, r, i):
    """Load element [r, i] (r static, i dynamic) of a 2-D ref -> scalar."""
    return ref[r:r + 1, pl.ds(i, 1)].reshape(())


def pad_to_multiple(x: jax.Array, multiple: int, axis: int, *,
                    value: float = 0.0) -> tuple[jax.Array, int]:
    """Pad ``axis`` of ``x`` up to a multiple of ``multiple`` with ``value``.
    Returns (padded, original size along axis)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, rem)
        x = jnp.pad(x, pads, constant_values=value)
    return x, size


def pad_lanes(x: jax.Array, block_m: int, *,
              identity: bool = False) -> tuple[jax.Array, int]:
    """Pad the minor (system) axis of an interleaved (N, M) batch to a
    multiple of the lane tile. Returns (padded, original_M).

    ``identity=True`` pads with ones instead of zeros — required for the
    MAIN diagonal of per-system-LHS (batch mode) operands, so the dead
    padded lanes factor as identity rows (1/1) instead of dividing by the
    zero pad (1/0 -> inf/NaN poisoning every dead lane).  The ``sharded``
    backend's mesh padding shares this helper for the same reason.
    """
    return pad_to_multiple(x, block_m, -1, value=1.0 if identity else 0.0)


def shard_lanes(m: int, n_shards: int) -> int:
    """Per-device lane count after the mesh padding of the ``sharded``
    backend: M pads to a multiple of the shard count, then splits evenly.

    This is the lane count the per-device auto-tuner and the sharded
    traffic model reason about — each device's kernels additionally pad
    their local slice to the lane-tile multiple (``pad_lanes``)."""
    return -(-m // n_shards)


def pad_sweep(x: jax.Array, block_n: int, axis: int = 0, *,
              identity: bool = False) -> tuple[jax.Array, int]:
    """Zero-pad the sweep (N) axis to a multiple of the streamed N-chunk.

    Zero padding is exact for the *factored* constant-LHS kernels: a padded
    row computes ``(0 - 0*carry) * 0 = 0``, so padded rows contribute
    nothing to the forward carries and back-substitute to exactly 0 —
    finite under ``JAX_DEBUG_NANS`` (no division happens in the solve
    kernels; the inverses were taken at factor time).

    ``identity=True`` pads with ones instead — required for the MAIN
    diagonal of per-lane (batch-mode) operands, whose fused factorisation
    DOES divide in-kernel: an all-zero padded row would compute
    ``1/(0 - 0) = inf``, while an identity row factors as ``1/1`` and
    back-substitutes to exactly 0 (the sweep-axis analogue of
    ``pad_lanes(identity=True)``)."""
    return pad_to_multiple(x, block_n, axis, value=1.0 if identity else 0.0)


def vmem_working_set(n: int, block_m: int, n_rhs_blocks: int, n_lhs_vecs: int,
                     itemsize: int = 4) -> int:
    """Bytes of VMEM a solver grid step holds: RHS/out blocks + shared LHS."""
    return (n_rhs_blocks * n * block_m + n_lhs_vecs * n) * itemsize


def streamed_vmem_working_set(block_n: int, block_m: int, n_rhs_blocks: int,
                              n_lhs_vecs: int, n_carry: int,
                              itemsize: int = 4) -> int:
    """Bytes of VMEM a *streamed* (split-N) grid step holds: the N-chunked
    RHS/out blocks + the N-chunked shared LHS + the carry rows that thread
    the sweep state across sequential N-chunks."""
    return (n_rhs_blocks * block_n * block_m + n_lhs_vecs * block_n
            + n_carry * block_m) * itemsize


def check_vmem(n: int, block_m: int, n_rhs_blocks: int, n_lhs_vecs: int,
               itemsize: int = 4) -> None:
    ws = vmem_working_set(n, block_m, n_rhs_blocks, n_lhs_vecs,
                          itemsize=itemsize)
    if ws > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"solver working set {ws/2**20:.1f} MiB exceeds VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB): N={n}, BLOCK_M={block_m}. "
            f"Reduce block_m or split N (HBM-streamed variant).")


def check_vmem_streamed(block_n: int, block_m: int, n_rhs_blocks: int,
                        n_lhs_vecs: int, n_carry: int,
                        itemsize: int = 4) -> None:
    ws = streamed_vmem_working_set(block_n, block_m, n_rhs_blocks, n_lhs_vecs,
                                   n_carry, itemsize=itemsize)
    if ws > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"streamed solver working set {ws/2**20:.1f} MiB exceeds VMEM "
            f"budget ({VMEM_BUDGET_BYTES/2**20:.0f} MiB): BLOCK_N={block_n}, "
            f"BLOCK_M={block_m}. Reduce block_n or block_m.")


# -- streamed (split-N) grid plumbing ---------------------------------------
#
# The streamed kernels run on a 2-D grid ``(M/block_m, N/block_n)``.  The
# LAST grid axis iterates fastest on TPU, so for a fixed lane tile j the
# N-chunks execute sequentially — the sweep state (``dh_prev`` / the penta
# second-order carries) lives in a small VMEM scratch that persists across
# those grid steps.  The forward-sweep kernel walks chunks ascending in N;
# the back-substitution kernel walks them descending (its index_map reverses
# the chunk axis), the TPU analogue of the paper's 2-kernel pipeline.

def _imin(a, b):
    """Branch-free min that works on Python ints AND traced grid indices
    (index maps trace; ``min``/``jnp.minimum`` would concretise or force a
    jnp dependency inside the map)."""
    return (a + b - abs(a - b)) // 2


def chunk_spec(block_n: int, block_m: int, num_n: int, *,
               reverse: bool = False):
    """BlockSpec for an (N, M) operand chunked to (block_n, block_m) on the
    streamed grid (j = lane tile, k = N-chunk; ``num_n`` chunks total)."""
    if reverse:
        return pl.BlockSpec((block_n, block_m),
                            lambda j, k: (num_n - 1 - k, j))
    return pl.BlockSpec((block_n, block_m), lambda j, k: (k, j))


def chunk_lhs_spec(rows: int, block_n: int, num_n: int, *,
                   reverse: bool = False):
    """BlockSpec for a stacked (rows, N) shared LHS chunked along N.  Every
    lane tile re-walks the same chunks — the single stored LHS copy of the
    paper, streamed through VMEM instead of resident."""
    if reverse:
        return pl.BlockSpec((rows, block_n),
                            lambda j, k: (0, num_n - 1 - k))
    return pl.BlockSpec((rows, block_n), lambda j, k: (0, k))


# -- fused single-call streamed grid ----------------------------------------
#
# The fused streamed kernels run BOTH sweep passes in one ``pallas_call`` on
# a grid ``(M/block_m, 2*N/block_n)`` whose N-chunk walk ASCENDS for the
# first num_n steps (forward pass) and DESCENDS for the last num_n steps
# (back substitution), with the intermediate (d_hat / g) held in a full-N
# VMEM scratch instead of round-tripping through HBM between two kernels.
# The index maps below clamp each operand to the phase that actually uses
# it, so every HBM block is fetched exactly once per phase that needs it
# (the clamped steps revisit the previous block, which Pallas keeps in VMEM
# — no refetch, and the recount in analysis/capture counts distinct blocks).

def fused_chunk_spec(block_n: int, block_m: int, num_n: int, *, phase: str):
    """BlockSpec for an (N, M) operand on the fused ascend/descend grid.

    ``phase="ascend"`` (forward-pass inputs): chunk ``min(k, num_n-1)`` —
    walks 0..num_n-1, then parks on the last chunk through the descend
    steps (already in VMEM; the descend phase never reads it).
    ``phase="descend"`` (back-substitution output): chunk
    ``min(2*num_n-1-k, num_n-1)`` — parks on chunk num_n-1 through the
    ascend steps (those writes are dead: the first descend step rewrites
    the same block), then walks num_n-1..0."""
    if phase == "ascend":
        return pl.BlockSpec((block_n, block_m),
                            lambda j, k: (_imin(k, num_n - 1), j))
    if phase != "descend":
        raise ValueError(f"phase must be 'ascend' or 'descend', got {phase!r}")
    return pl.BlockSpec((block_n, block_m),
                        lambda j, k: (_imin(2 * num_n - 1 - k, num_n - 1), j))


def fused_lhs_spec(rows: int, block_n: int, num_n: int):
    """BlockSpec for the stacked (rows, N) shared LHS on the fused grid:
    the descend phase MIRRORS the ascend walk (``min(k, 2*num_n-1-k)``
    is 0..num_n-1 then num_n-1..0), so the single stored LHS copy streams
    through VMEM exactly once per phase with no refetch at the turn."""
    return pl.BlockSpec((rows, block_n),
                        lambda j, k: (0, _imin(k, 2 * num_n - 1 - k)))


def fused_vmem_working_set(n: int, block_n: int, block_m: int,
                           n_chunk_blocks: int, n_lhs_vecs: int,
                           n_carry: int, n_sweep_blocks: int,
                           itemsize: int = 4,
                           compute_itemsize: int | None = None) -> int:
    """Bytes of VMEM a FUSED streamed grid step holds: the chunked
    operand/out blocks + chunked LHS (at the storage itemsize) + the carry
    rows and the full-N intermediate scratch that replaces the two-call
    pair's HBM round trip (at the fp32 compute itemsize)."""
    if compute_itemsize is None:
        compute_itemsize = itemsize
    return ((n_chunk_blocks * block_n * block_m + n_lhs_vecs * block_n)
            * itemsize
            + (n_carry * block_m + n_sweep_blocks * n * block_m)
            * compute_itemsize)


def check_vmem_fused(n: int, block_n: int, block_m: int, n_chunk_blocks: int,
                     n_lhs_vecs: int, n_carry: int, n_sweep_blocks: int,
                     itemsize: int = 4,
                     compute_itemsize: int | None = None) -> None:
    ws = fused_vmem_working_set(n, block_n, block_m, n_chunk_blocks,
                                n_lhs_vecs, n_carry, n_sweep_blocks,
                                itemsize=itemsize,
                                compute_itemsize=compute_itemsize)
    if ws > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused streamed working set {ws/2**20:.1f} MiB exceeds VMEM "
            f"budget ({VMEM_BUDGET_BYTES/2**20:.0f} MiB): N={n}, "
            f"BLOCK_N={block_n}, BLOCK_M={block_m}. The full-N intermediate "
            f"scratch does not fit — spill to the two-call streamed pair "
            f"(fused=False) or reduce block_m.")


def block_shape_of(block_spec) -> tuple:
    """The (static) block shape of a ``pl.BlockSpec`` — a version-stable
    accessor for the static-analysis layer (``repro.analysis``), which
    enumerates kernel grids without running them."""
    return tuple(block_spec.block_shape)


def index_map_of(block_spec):
    """The index-map callable of a ``pl.BlockSpec`` (grid indices ->
    block indices).  ``repro.analysis.gridcheck`` enumerates this map over
    the whole grid to prove write coverage and chunk-walk mirroring."""
    return block_spec.index_map


def reset_carry(carry_ref, k) -> None:
    """Zero the carry scratch on the first N-chunk of each lane tile.

    Zero-init makes the boundary rows fall out of the *general* recurrence
    (e.g. ``dh_0 = (d_0 - a_0·0)·inv_0``), so the streamed kernels need no
    first/last-row special cases and no cross-chunk peeking."""
    @pl.when(k == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)
