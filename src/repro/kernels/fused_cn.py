"""Beyond-paper Pallas kernel: fused Crank-Nicolson step for periodic 1-D
diffusion (stencil RHS + Thomas solve + Sherman-Morrison correction in ONE
kernel).

The paper's pipeline is:  cuSten stencil kernel (writes RHS to RAM) ->
cuThomasConstantBatch (reads RHS, writes y) -> S-M correction (reads y,
writes x): ~6 N M words of HBM traffic per time step. Fusing the three into
one kernel the field is read once and the result written once: ~2 N M words
(a predicted ~3x reduction of the memory-roofline term; see EXPERIMENTS.md
§Perf for the accounting).

Inputs per block:
    lhs_ref: (3, N)  [a, inv_denom, c_hat] of the S-M core matrix A'
    z_ref:   (N, 1)  z = A'^{-1} u (periodic correction direction)
    p_ref:   (1, 8)  scalars [sl, sc, sr, v_last, inv_denom_sm, 0, 0, 0]
                     (sl, sc, sr) = explicit CN stencil (sigma, 1-2sigma, sigma)
    c_ref:   (N, BLOCK_M) current field C^n (interleaved)
    x_ref:   (N, BLOCK_M) -> C^{n+1}
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import row, scalar, store_row


def fused_cn_tridiag_kernel(lhs_ref, z_ref, p_ref, c_ref, x_ref, *,
                            n: int, unroll: int):
    m = c_ref.shape[1]
    sl = scalar(p_ref, 0, 0)
    sc = scalar(p_ref, 0, 1)
    sr = scalar(p_ref, 0, 2)
    v_last = scalar(p_ref, 0, 3)
    inv_sm = scalar(p_ref, 0, 4)

    def rhs(i):
        # periodic 3-point stencil, all rows VMEM-resident
        im1 = jnp.where(i == 0, n - 1, i - 1)
        ip1 = jnp.where(i == n - 1, 0, i + 1)
        return (sl * row(c_ref, im1, m) + sc * row(c_ref, i, m)
                + sr * row(c_ref, ip1, m))

    # forward sweep of A' (d_hat stored into x_ref)
    dh = rhs(0) * scalar(lhs_ref, 1, 0)
    store_row(x_ref, 0, dh)

    def fwd(i, dh_prev):
        a_i = scalar(lhs_ref, 0, i)
        inv_i = scalar(lhs_ref, 1, i)
        dh_i = (rhs(i) - a_i * dh_prev) * inv_i
        store_row(x_ref, i, dh_i)
        return dh_i

    y_last = jax.lax.fori_loop(1, n, fwd, dh, unroll=unroll)  # y_{N-1}

    # backward sweep -> y in x_ref
    def bwd(k, x_next):
        i = n - 2 - k
        y_i = row(x_ref, i, m) - scalar(lhs_ref, 2, i) * x_next
        store_row(x_ref, i, y_i)
        return y_i

    y0 = jax.lax.fori_loop(0, n - 1, bwd, y_last, unroll=unroll)  # y_0

    # fused Sherman-Morrison correction: x = y - ((v.y) / (1 + v.z)) z
    corr = (y0 + v_last * y_last) * inv_sm          # (BLOCK_M,)
    x_ref[...] = x_ref[...] - corr[None, :] * z_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "unroll", "interpret"))
def fused_cn_tridiag_pallas(lhs, z, params, c, *, block_m: int = 128,
                            unroll: int = 1, interpret: bool = True):
    """One periodic CN diffusion time step. c: (N, M) -> (N, M)."""
    n, m = c.shape
    col = pl.BlockSpec((n, block_m), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(fused_cn_tridiag_kernel, n=n, unroll=unroll),
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((3, n), lambda j: (0, 0)),
                  pl.BlockSpec((n, 1), lambda j: (0, 0)),
                  pl.BlockSpec((1, 8), lambda j: (0, 0)),
                  col],
        out_specs=col,
        out_shape=jax.ShapeDtypeStruct((n, m), c.dtype),
        interpret=interpret,
    )(lhs, z, params, c)


def hbm_traffic_bytes(n: int, m: int, dtype=jnp.float32) -> dict:
    """Fused vs the paper's 3-kernel pipeline (per CN step)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "fused": (2 * n * m + 4 * n + 8) * itemsize,
        "unfused_pipeline": (6 * n * m + 4 * n + 8) * itemsize,
    }
