"""Beyond-paper Pallas kernel #2: fused Crank-Nicolson step for periodic 1-D
HYPERDIFFUSION (paper §IV benchmark) — 5-point stencil RHS + pentadiagonal
LR solve + rank-4 Woodbury periodic correction in ONE kernel.

Same argument as fused_cn.py: the paper's pipeline (cuSten RHS kernel ->
cuPentConstantBatch -> correction) moves ~6 N M words of HBM per time step;
fused it is ~2 N M (read C^n once, write C^{n+1} once).

Inputs per block:
    lhs_ref:  (5, N)  [eps, beta, inv_alpha, gamma, delta] of A'
    z_ref:    (N, 4)  Z = A'^{-1} U (Woodbury directions)
    minv_ref: (4, 4)  (I + V^T Z)^{-1}
    p_ref:    (1, 16) [sm2, sm1, s0, sp1, sp2,  a0, b0, a1, eN2, dN1, eN1, ...]
                      (5 CN stencil weights + 6 wrap coefficients)
    c_ref:    (N, BLOCK_M) current field -> x_ref: (N, BLOCK_M) next field
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import row, scalar, store_row

EPS, BETA, INV_ALPHA, GAMMA, DELTA = range(5)


def fused_cn_penta_kernel(lhs_ref, z_ref, minv_ref, p_ref, c_ref, x_ref, *,
                          n: int, unroll: int):
    m = c_ref.shape[1]
    w = [scalar(p_ref, 0, i) for i in range(5)]              # stencil
    a0, b0, a1, eN2, dN1, eN1 = (scalar(p_ref, 0, 5 + i) for i in range(6))

    def rhs(i):
        idx = [jnp.where(i + off < 0, i + off + n,
                         jnp.where(i + off >= n, i + off - n, i + off))
               for off in (-2, -1, 0, 1, 2)]
        acc = w[0] * row(c_ref, idx[0], m)
        for t in range(1, 5):
            acc = acc + w[t] * row(c_ref, idx[t], m)
        return acc

    # ---- forward: g_i = (rhs_i - eps_i g_{i-2} - beta_i g_{i-1}) inv_i ----
    g0 = rhs(0) * scalar(lhs_ref, INV_ALPHA, 0)
    store_row(x_ref, 0, g0)
    g1 = (rhs(1) - scalar(lhs_ref, BETA, 1) * g0) * scalar(lhs_ref, INV_ALPHA, 1)
    store_row(x_ref, 1, g1)

    def fwd(i, carry):
        gm1, gm2 = carry
        g = (rhs(i) - scalar(lhs_ref, EPS, i) * gm2
             - scalar(lhs_ref, BETA, i) * gm1) * scalar(lhs_ref, INV_ALPHA, i)
        store_row(x_ref, i, g)
        return g, gm1

    gN1, gN2 = jax.lax.fori_loop(2, n, fwd, (g1, g0), unroll=unroll)

    # ---- backward: y_i = g_i - gamma_i y_{i+1} - delta_i y_{i+2} ----------
    y_last = gN1                                             # y_{N-1}
    y_prev = gN2 - scalar(lhs_ref, GAMMA, n - 2) * y_last    # y_{N-2}
    store_row(x_ref, n - 2, y_prev)

    def bwd(k, carry):
        yp1, yp2 = carry
        i = n - 3 - k
        y_i = (row(x_ref, i, m) - scalar(lhs_ref, GAMMA, i) * yp1
               - scalar(lhs_ref, DELTA, i) * yp2)
        store_row(x_ref, i, y_i)
        return y_i, yp1

    y0, y1 = jax.lax.fori_loop(0, n - 2, bwd, (y_prev, y_last), unroll=unroll)
    # after the loop: y0 = y_0, y1 = y_1 (the last two computed rows)

    # ---- fused rank-4 Woodbury correction: x = y - Z (I+V^T Z)^-1 V^T y ---
    yN2 = row(x_ref, n - 2, m)
    yN1 = row(x_ref, n - 1, m)
    vty = [a0 * yN2 + b0 * yN1,
           a1 * yN1,
           eN2 * y0,
           dN1 * y0 + eN1 * y1]                              # 4 x (M,)
    wvec = []
    for r_i in range(4):
        acc = scalar(minv_ref, r_i, 0) * vty[0]
        for c_i in range(1, 4):
            acc = acc + scalar(minv_ref, r_i, c_i) * vty[c_i]
        wvec.append(acc)
    wmat = jnp.stack(wvec, axis=0)                           # (4, M)
    corr = jnp.dot(z_ref[...].astype(jnp.float32), wmat,
                   preferred_element_type=jnp.float32)       # (N, M) via MXU
    x_ref[...] = x_ref[...] - corr.astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "unroll", "interpret"))
def fused_cn_penta_pallas(lhs, z, minv, params, c, *, block_m: int = 128,
                          unroll: int = 1, interpret: bool = True):
    n, m = c.shape
    col = pl.BlockSpec((n, block_m), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(fused_cn_penta_kernel, n=n, unroll=unroll),
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((5, n), lambda j: (0, 0)),
                  pl.BlockSpec((n, 4), lambda j: (0, 0)),
                  pl.BlockSpec((4, 4), lambda j: (0, 0)),
                  pl.BlockSpec((1, 16), lambda j: (0, 0)),
                  col],
        out_specs=col,
        out_shape=jax.ShapeDtypeStruct((n, m), c.dtype),
        interpret=interpret,
    )(lhs, z, minv, params, c)


def hbm_traffic_bytes(n: int, m: int, dtype=jnp.float32) -> dict:
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "fused": (2 * n * m + 9 * n + 32) * itemsize,
        "unfused_pipeline": (6 * n * m + 9 * n + 32) * itemsize,
    }
