"""Pure-jnp oracles for every Pallas kernel in this package.

These are thin compositions of ``repro.core`` (already validated against
dense ``np.linalg.solve`` oracles in tests/test_core_solvers.py), so the
kernel tests form a chain: Pallas kernel == ref == dense solve.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import (
    PeriodicTridiagFactor,
    TridiagFactor,
    PentaFactor,
    penta_factor_solve,
    penta_solve,
    periodic_thomas_solve,
    thomas_factor_solve,
    thomas_solve,
)


def thomas_constant_ref(lhs: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """lhs: (3, N) stacked [a, inv_denom, c_hat]."""
    f = TridiagFactor(a=lhs[0], inv_denom=lhs[1], c_hat=lhs[2])
    return thomas_solve(f, d)


def thomas_batch_ref(a, b, c, d) -> jnp.ndarray:
    return thomas_factor_solve(a, b, c, d)


def penta_constant_ref(lhs: jnp.ndarray, f: jnp.ndarray,
                       uniform_eps: float | None = None) -> jnp.ndarray:
    """lhs: (5, N) [eps, beta, inv_alpha, gamma, delta]; (4, N) if uniform."""
    if uniform_eps is None:
        fac = PentaFactor(eps=lhs[0], beta=lhs[1], inv_alpha=lhs[2],
                          gamma=lhs[3], delta=lhs[4])
    else:
        n = lhs.shape[1]
        eps = jnp.full((n,), uniform_eps, lhs.dtype).at[jnp.array([0, 1])].set(0)
        fac = PentaFactor(eps=eps, beta=lhs[0], inv_alpha=lhs[1],
                          gamma=lhs[2], delta=lhs[3])
    return penta_solve(fac, f)


def penta_batch_ref(a, b, c, d, e, f) -> jnp.ndarray:
    return penta_factor_solve(a, b, c, d, e, f)


def fused_cn_tridiag_ref(pf: PeriodicTridiagFactor, sigma: float,
                         c: jnp.ndarray) -> jnp.ndarray:
    """One periodic CN diffusion step: explicit stencil then periodic solve."""
    rhs = (sigma * jnp.roll(c, 1, axis=0)
           + (1.0 - 2.0 * sigma) * c
           + sigma * jnp.roll(c, -1, axis=0))
    return periodic_thomas_solve(pf, rhs)
