"""Pallas TPU kernels: batched Thomas solves, constant-LHS vs per-system LHS.

cuThomasConstantBatch (paper) -> ``thomas_constant_kernel``:
    * RHS block   (N, BLOCK_M) — interleaved, one system per lane.
    * LHS block   (3, N)       — a / inv_denom / c_hat stored ONCE; its
      BlockSpec index_map is constant so the same VMEM block serves every
      grid step (the broadcast-read of the paper, made explicit).
    * HBM traffic per block: (N*BLOCK_M) in + (N*BLOCK_M) out + 3N shared.

cuThomasBatch (baseline, prior SoTA) -> ``thomas_batch_kernel``:
    * each lane owns its LHS: three (N, BLOCK_M) diagonal blocks + RHS.
    * factorisation is fused into the solve (the real cuThomasBatch destroys
      the LHS copy in-place, forcing a re-factor each step).
    * HBM traffic per block: 4*(N*BLOCK_M) in + (N*BLOCK_M) out.

The sweeps are sequential in N (Thomas is inherently serial per system) and
vectorised across 128 lanes; ``unroll`` trades instruction count for VREG
pressure along the sublane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import row, scalar, store_row


def thomas_constant_kernel(lhs_ref, d_ref, x_ref, *, n: int, unroll: int):
    """lhs_ref: (3, N) = [a, inv_denom, c_hat];  d_ref/x_ref: (N, BLOCK_M)."""
    m = d_ref.shape[1]

    # --- forward sweep: d_hat_i = (d_i - a_i d_hat_{i-1}) * inv_i ----------
    dh0 = row(d_ref, 0, m) * scalar(lhs_ref, 1, 0)
    store_row(x_ref, 0, dh0)

    def fwd(i, dh_prev):
        a_i = scalar(lhs_ref, 0, i)
        inv_i = scalar(lhs_ref, 1, i)
        dh = (row(d_ref, i, m) - a_i * dh_prev) * inv_i
        store_row(x_ref, i, dh)
        return dh

    last = jax.lax.fori_loop(1, n, fwd, dh0, unroll=unroll)

    # --- backward sweep: x_i = d_hat_i - c_hat_i x_{i+1} -------------------
    def bwd(k, x_next):
        i = n - 2 - k
        x_i = row(x_ref, i, m) - scalar(lhs_ref, 2, i) * x_next
        store_row(x_ref, i, x_i)
        return x_i

    jax.lax.fori_loop(0, n - 1, bwd, last, unroll=unroll)


def thomas_batch_kernel(a_ref, b_ref, c_ref, d_ref, x_ref, scratch_ref, *,
                        n: int, unroll: int):
    """Per-system LHS baseline; factor fused with solve (cuThomasBatch).

    a/b/c/d: (N, BLOCK_M) per-lane copies. scratch holds c_hat (N, BLOCK_M).
    """
    m = d_ref.shape[1]
    inv0 = 1.0 / row(b_ref, 0, m)
    chat0 = row(c_ref, 0, m) * inv0
    store_row(scratch_ref, 0, chat0)
    dh0 = row(d_ref, 0, m) * inv0
    store_row(x_ref, 0, dh0)

    def fwd(i, carry):
        chat_prev, dh_prev = carry
        a_i = row(a_ref, i, m)
        inv = 1.0 / (row(b_ref, i, m) - a_i * chat_prev)
        chat = row(c_ref, i, m) * inv
        store_row(scratch_ref, i, chat)
        dh = (row(d_ref, i, m) - a_i * dh_prev) * inv
        store_row(x_ref, i, dh)
        return chat, dh

    _, last = jax.lax.fori_loop(1, n, fwd, (chat0, dh0), unroll=unroll)

    def bwd(k, x_next):
        i = n - 2 - k
        x_i = row(x_ref, i, m) - row(scratch_ref, i, m) * x_next
        store_row(x_ref, i, x_i)
        return x_i

    jax.lax.fori_loop(0, n - 1, bwd, last, unroll=unroll)


def _const_lhs_spec(n: int):
    # constant index_map: the SAME (3, N) block for every grid step — the
    # single global LHS copy.
    return pl.BlockSpec((3, n), lambda j: (0, 0))


def _col_spec(n: int, block_m: int):
    return pl.BlockSpec((n, block_m), lambda j: (0, j))


@functools.partial(jax.jit, static_argnames=("block_m", "unroll", "interpret"))
def thomas_constant_pallas(lhs: jax.Array, d: jax.Array, *, block_m: int = 128,
                           unroll: int = 1, interpret: bool = True) -> jax.Array:
    """lhs: (3, N) stacked [a, inv_denom, c_hat]; d: (N, M), M % block_m == 0."""
    n, m = d.shape
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(thomas_constant_kernel, n=n, unroll=unroll),
        grid=grid,
        in_specs=[_const_lhs_spec(n), _col_spec(n, block_m)],
        out_specs=_col_spec(n, block_m),
        out_shape=jax.ShapeDtypeStruct((n, m), d.dtype),
        interpret=interpret,
    )(lhs, d)


@functools.partial(jax.jit, static_argnames=("block_m", "unroll", "interpret"))
def thomas_batch_pallas(a, b, c, d, *, block_m: int = 128,
                        unroll: int = 1, interpret: bool = True) -> jax.Array:
    """Baseline: a/b/c/d all (N, M) per-system interleaved copies."""
    n, m = d.shape
    grid = (m // block_m,)
    spec = _col_spec(n, block_m)
    return pl.pallas_call(
        functools.partial(thomas_batch_kernel, n=n, unroll=unroll),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, m), d.dtype),
        scratch_shapes=[pltpu.VMEM((n, block_m), d.dtype)],
        interpret=interpret,
    )(a, b, c, d)


def hbm_traffic_bytes(n: int, m: int, dtype=jnp.float32) -> dict:
    """Analytic HBM<->VMEM traffic — the quantity the paper's speed-up comes
    from (roofline memory term for these bandwidth-bound kernels).
    ``itemsize`` derives from the actual dtype (fp64 runs are no longer
    under-counted by a hardcoded 4)."""
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "constant": (n * m * 2 + 3 * n) * itemsize,      # RHS in + x out + LHS once/block*
        "batch": (n * m * 5) * itemsize,                 # 3 diagonals + RHS in, x out
        # streamed (split-N, thomas_streamed.py): the intermediate d_hat
        # makes one extra HBM round trip (fwd pass writes it, bwd pass reads
        # it) and both passes re-stream the shared LHS — 2x the resident
        # constant traffic, still < the 5 N M per-system baseline.
        "constant_streamed": (n * m * 4 + 2 * 3 * n) * itemsize,
        # *the shared LHS re-fetch is once per grid block, negligible for M >> block
    }
