"""Public jit'd entry points for the solver kernels.

These wrap the raw ``pallas_call`` kernels with:
  * factored-LHS stacking from ``repro.core`` factor types,
  * lane padding (the batch axis is padded to the lane-tile multiple),
  * automatic ``interpret=True`` off-TPU (validation mode on CPU),
  * VMEM-budget checks,
  * an optional ``shard_map`` distribution over the system/batch axis — the
    paper's single-LHS idea at cluster scale: ONE LHS copy per device
    (replicated), RHS systems sharded across the mesh, zero collectives in
    the solve (embarrassingly parallel over M).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import (PentaFactor, PeriodicPentaFactor,
                        PeriodicTridiagFactor, TridiagFactor)
from .common import (check_vmem, check_vmem_streamed, default_interpret,
                     pad_lanes, pad_sweep)
from .fused_cn import fused_cn_tridiag_pallas
from .fused_cn_penta import fused_cn_penta_pallas
from .penta import penta_batch_pallas, penta_constant_pallas
from .penta_streamed import penta_constant_streamed_pallas
from .thomas import thomas_batch_pallas, thomas_constant_pallas
from .thomas_streamed import thomas_constant_streamed_pallas


def stack_tridiag_lhs(f: TridiagFactor) -> jax.Array:
    return jnp.stack([f.a, f.inv_denom, f.c_hat])


def stack_penta_lhs(f: PentaFactor, uniform: bool = False) -> jax.Array:
    if uniform:
        return jnp.stack([f.beta, f.inv_alpha, f.gamma, f.delta])
    eps = jnp.broadcast_to(f.eps, f.beta.shape)
    return jnp.stack([eps, f.beta, f.inv_alpha, f.gamma, f.delta])


def thomas_constant(f: TridiagFactor, d: jax.Array, *, block_m: int = 128,
                    block_n: int | None = None, unroll: int = 1,
                    interpret: bool | None = None) -> jax.Array:
    """Constant-LHS batched Thomas solve (cuThomasConstantBatch). d: (N, M).

    ``block_n=None`` runs the VMEM-resident kernel (full N per grid step);
    an integer ``block_n`` runs the HBM-streamed split-N kernel pair, which
    lifts the VMEM wall for large N (``thomas_streamed.py``)."""
    if interpret is None:
        interpret = default_interpret()
    n = d.shape[0]
    if block_n is None:
        check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=3,
                   itemsize=d.dtype.itemsize)
        d_pad, m = pad_lanes(d, block_m)
        x = thomas_constant_pallas(stack_tridiag_lhs(f), d_pad,
                                   block_m=block_m, unroll=unroll,
                                   interpret=interpret)
        return x[:, :m]
    check_vmem_streamed(block_n, block_m, n_rhs_blocks=2, n_lhs_vecs=3,
                        n_carry=1, itemsize=d.dtype.itemsize)
    lhs, _ = pad_sweep(stack_tridiag_lhs(f), block_n, axis=1)
    d_pad, m = pad_lanes(d, block_m)
    d_pad, _ = pad_sweep(d_pad, block_n, axis=0)
    x = thomas_constant_streamed_pallas(lhs, d_pad, block_m=block_m,
                                        block_n=block_n, unroll=unroll,
                                        interpret=interpret)
    return x[:n, :m]


def thomas_batch(a, b, c, d, *, block_m: int = 128, unroll: int = 1,
                 interpret: bool | None = None) -> jax.Array:
    """Per-system-LHS baseline (cuThomasBatch). a/b/c/d: (N, M).

    Dead padded lanes get an IDENTITY main diagonal (b = 1), not the zero
    pad — the fused factorisation would otherwise compute 1/0 and flood
    the padding with inf/NaN (they are sliced off, but they poison
    ``JAX_DEBUG_NANS`` runs and waste the flush-to-zero path)."""
    if interpret is None:
        interpret = default_interpret()
    n = d.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=6, n_lhs_vecs=0,
               itemsize=d.dtype.itemsize)  # 3 diag + rhs + out + scratch
    m = d.shape[1]
    args = [pad_lanes(x, block_m, identity=ident)[0]
            for x, ident in ((a, False), (b, True), (c, False), (d, False))]
    x = thomas_batch_pallas(*args, block_m=block_m, unroll=unroll,
                            interpret=interpret)
    return x[:, :m]


def _uniform_eps_param(f: PentaFactor, dtype) -> jax.Array:
    """The all-equal eps value as a (1, 1) ARRAY operand.

    Must stay an array end to end: ``float(f.eps[2])`` on a traced
    ``Factorization`` leaf raises ``ConcretizationTypeError`` under
    ``jax.jit(solve)`` / ``lax.scan`` PDE loops.  Index [2] because the
    factor forces eps[0] = eps[1] = 0 (outside the matrix)."""
    eps = jnp.broadcast_to(jnp.asarray(f.eps), f.beta.shape)
    return eps[2].reshape(1, 1).astype(dtype)


def penta_constant(f: PentaFactor, rhs: jax.Array, *, block_m: int = 128,
                   block_n: int | None = None, unroll: int = 1,
                   interpret: bool | None = None,
                   uniform: bool = False) -> jax.Array:
    """Constant-LHS batched penta solve (cuPentConstantBatch /
    cuPentUniformBatch when ``uniform``).  ``block_n`` selects the
    HBM-streamed split-N kernel pair (``penta_streamed.py``)."""
    if interpret is None:
        interpret = default_interpret()
    n = rhs.shape[0]
    eps = _uniform_eps_param(f, rhs.dtype) if uniform else None
    lhs = stack_penta_lhs(f, uniform=uniform)
    if block_n is None:
        check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=5,
                   itemsize=rhs.dtype.itemsize)
        rhs_pad, m = pad_lanes(rhs, block_m)
        x = penta_constant_pallas(lhs, rhs_pad, block_m=block_m,
                                  unroll=unroll, interpret=interpret,
                                  uniform=uniform, eps=eps)
        return x[:, :m]
    check_vmem_streamed(block_n, block_m, n_rhs_blocks=2, n_lhs_vecs=5,
                        n_carry=2, itemsize=rhs.dtype.itemsize)
    lhs, _ = pad_sweep(lhs, block_n, axis=1)
    rhs_pad, m = pad_lanes(rhs, block_m)
    rhs_pad, _ = pad_sweep(rhs_pad, block_n, axis=0)
    x = penta_constant_streamed_pallas(lhs, rhs_pad, block_m=block_m,
                                       block_n=block_n, unroll=unroll,
                                       interpret=interpret, uniform=uniform,
                                       eps=eps)
    return x[:n, :m]


def penta_batch(a, b, c, d, e, rhs, *, block_m: int = 128, unroll: int = 1,
                interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    n = rhs.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=9, n_lhs_vecs=0,
               itemsize=rhs.dtype.itemsize)
    m = rhs.shape[1]
    # identity-pad the MAIN diagonal c (see thomas_batch): dead lanes must
    # factor as identity rows, not divide by the zero pad.
    args = [pad_lanes(x, block_m, identity=ident)[0]
            for x, ident in ((a, False), (b, False), (c, True), (d, False),
                             (e, False), (rhs, False))]
    x = penta_batch_pallas(*args, block_m=block_m, unroll=unroll,
                           interpret=interpret)
    return x[:, :m]


def fused_cn_step(pf: PeriodicTridiagFactor, sigma: float, c: jax.Array, *,
                  block_m: int = 128, unroll: int = 1,
                  interpret: bool | None = None) -> jax.Array:
    """Fused periodic CN diffusion step (beyond-paper; see fused_cn.py)."""
    if interpret is None:
        interpret = default_interpret()
    n = c.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=4,
               itemsize=c.dtype.itemsize)
    lhs = stack_tridiag_lhs(pf.factor)
    z = pf.z.reshape(n, 1)
    params = jnp.zeros((1, 8), c.dtype)
    params = params.at[0, 0].set(sigma).at[0, 1].set(1 - 2 * sigma) \
                   .at[0, 2].set(sigma).at[0, 3].set(pf.v_last) \
                   .at[0, 4].set(pf.inv_denom_sm)
    c_pad, m = pad_lanes(c, block_m)
    x = fused_cn_tridiag_pallas(lhs, z, params, c_pad, block_m=block_m,
                                unroll=unroll, interpret=interpret)
    return x[:, :m]


def fused_cn_penta_step(pf: PeriodicPentaFactor, sigma: float, c: jax.Array,
                        *, block_m: int = 128, unroll: int = 1,
                        interpret: bool | None = None) -> jax.Array:
    """Fused periodic CN hyperdiffusion step (beyond-paper #2;
    see fused_cn_penta.py). c: (N, M) -> (N, M)."""
    if interpret is None:
        interpret = default_interpret()
    n = c.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=10,
               itemsize=c.dtype.itemsize)
    lhs = stack_penta_lhs(pf.factor)
    params = jnp.zeros((1, 16), c.dtype)
    stencil = [-sigma, 4 * sigma, 1 - 6 * sigma, 4 * sigma, -sigma]
    for i, v in enumerate(stencil):
        params = params.at[0, i].set(v)
    for i in range(6):
        params = params.at[0, 5 + i].set(pf.vcoef[i])
    c_pad, m = pad_lanes(c, block_m)
    x = fused_cn_penta_pallas(lhs, pf.Z, pf.Minv, params, c_pad,
                              block_m=block_m, unroll=unroll,
                              interpret=interpret)
    return x[:, :m]


# ---------------------------------------------------------------------------
# Analytic HBM traffic for one solve as dispatched by this module — the
# roofline memory term the paper's speed-up rests on, per storage mode and
# resident-vs-streamed kernel choice.
# ---------------------------------------------------------------------------

def solver_hbm_traffic_bytes(bandwidth: int, mode: str, n: int, m: int, *,
                             dtype=jnp.float32, streamed: bool = False) -> int:
    """Bytes moved HBM<->VMEM by one batched solve of an (n, m) RHS."""
    from . import penta as _penta_k
    from . import thomas as _thomas_k
    table = (_thomas_k if bandwidth == 3 else _penta_k).hbm_traffic_bytes(
        n, m, dtype=dtype)
    key = mode if mode in table else "constant"   # tridiag uniform == constant
    if streamed:
        key += "_streamed"
    if key not in table:
        raise ValueError(f"no traffic model for mode={mode!r} "
                         f"streamed={streamed} (bandwidth {bandwidth})")
    return table[key]


# ---------------------------------------------------------------------------
# Distributed batch solving: one LHS copy per DEVICE, systems sharded.
# ---------------------------------------------------------------------------

def sharded_solve(solve_fn, mesh: Mesh, batch_axes) -> callable:
    """Wrap a (factor, rhs (N, M)) -> x solver so the M axis is sharded over
    ``batch_axes`` of ``mesh`` and the factored LHS is replicated (the
    paper's storage saving, applied per-device). The solve needs no
    collectives — systems are independent.
    """
    from jax.experimental.shard_map import shard_map

    spec_rhs = P(None, batch_axes)
    fn = shard_map(solve_fn, mesh=mesh,
                   in_specs=(P(), spec_rhs), out_specs=spec_rhs,
                   check_rep=False)

    def wrapped(factor, rhs):
        return fn(factor, rhs)

    return wrapped
