"""Public jit'd entry points for the solver kernels.

These wrap the engine-generated ``pallas_call`` kernels
(``repro.kernels.engine``) with:
  * factored-LHS stacking from ``repro.core`` factor types — including the
    host-side row SHIFTS that turn the stored forward factor into the
    transposed kernels' coefficient rows (A^T = U^T·L^T needs c_hat_{i-1}
    / a_{i+1} etc., never a second factor),
  * lane padding (the batch axis is padded to the lane-tile multiple) and
    sweep padding (streamed kernels pad N to the chunk multiple; batch
    operands identity-pad the main diagonal on BOTH axes because the
    fused factorisation divides in-kernel),
  * automatic ``interpret=True`` off-TPU (validation mode on CPU),
  * spec-derived VMEM-budget checks,
  * an optional ``shard_map`` distribution over the system/batch axis — the
    paper's single-LHS idea at cluster scale: ONE LHS copy per device
    (replicated), RHS systems sharded across the mesh, zero collectives in
    the solve (embarrassingly parallel over M).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (PentaFactor, PeriodicPentaFactor,
                        PeriodicTridiagFactor, TridiagFactor)
from .common import (canonical_storage_dtype, check_vmem, check_vmem_fused,
                     check_vmem_streamed, default_interpret, pad_lanes,
                     pad_sweep)
from .engine import (RecurrenceSpec, SweepSpec, batch_solver,
                     find_recurrence_spec, find_spec, recurrence_solver,
                     shared_solver)
from .fused_cn import fused_cn_tridiag_pallas
from .fused_cn_penta import fused_cn_penta_pallas


def _shift_down(v: jax.Array, k: int) -> jax.Array:
    """Row i reads the stored vector at i-k (zeros shift in at the top)."""
    return jnp.concatenate([jnp.zeros_like(v[:k]), v[:-k]], axis=0)


def _shift_up(v: jax.Array, k: int) -> jax.Array:
    """Row i reads the stored vector at i+k (zeros shift in at the bottom)."""
    return jnp.concatenate([v[k:], jnp.zeros_like(v[:k])], axis=0)


def stack_tridiag_lhs(f: TridiagFactor, *,
                      transposed: bool = False) -> jax.Array:
    """(3, N) kernel LHS: [a, inv_denom, c_hat], or the transposed rows
    [c_hat_{i-1}, inv_denom, a_{i+1}] — same stored vectors, shifted."""
    if transposed:
        return jnp.stack([_shift_down(f.c_hat, 1), f.inv_denom,
                          _shift_up(f.a, 1)])
    return jnp.stack([f.a, f.inv_denom, f.c_hat])


def stack_penta_lhs(f: PentaFactor, uniform: bool = False, *,
                    transposed: bool = False) -> jax.Array:
    """(5, N) kernel LHS [eps, beta, inv_alpha, gamma, delta] ((4, N) when
    ``uniform`` drops the eps row); transposed: [delta_{i-2}, gamma_{i-1},
    inv_alpha, beta_{i+1}(, eps_{i+2})]."""
    if transposed:
        rows = [_shift_down(f.delta, 2), _shift_down(f.gamma, 1),
                f.inv_alpha, _shift_up(f.beta, 1)]
        if not uniform:
            eps = jnp.broadcast_to(f.eps, f.beta.shape)
            rows.append(_shift_up(eps, 2))
        return jnp.stack(rows)
    if uniform:
        return jnp.stack([f.beta, f.inv_alpha, f.gamma, f.delta])
    eps = jnp.broadcast_to(f.eps, f.beta.shape)
    return jnp.stack([eps, f.beta, f.inv_alpha, f.gamma, f.delta])


def _check_spec_vmem(spec: SweepSpec, n: int, block_m: int,
                     block_n: int | None, dtype,
                     storage_dtype=None) -> None:
    """Spec-derived working-set check (no hand-kept per-kernel counts).

    Mixed-precision storage sizes the streamed chunk operands at the
    storage itemsize and the carries / fused full-N intermediate scratch
    at the fp32-promoted compute itemsize."""
    n_rhs, n_lhs, n_carry = spec.vmem_counts()
    c_item = jnp.promote_types(dtype, jnp.float32).itemsize
    s_item = (storage_dtype or dtype).itemsize
    if getattr(spec, "fused", False):
        check_vmem_fused(n, block_n, block_m, n_rhs, n_lhs, n_carry,
                         spec.sweep_scratch(), itemsize=s_item,
                         compute_itemsize=c_item)
    elif block_n is None:
        check_vmem(n, block_m, n_rhs_blocks=n_rhs, n_lhs_vecs=n_lhs,
                   itemsize=c_item)
    else:
        check_vmem_streamed(block_n, block_m, n_rhs, n_lhs, n_carry,
                            itemsize=c_item)


def thomas_constant(f: TridiagFactor, d: jax.Array, *, block_m: int = 128,
                    block_n: int | None = None, unroll: int = 1,
                    interpret: bool | None = None,
                    transposed: bool = False, fused: bool = False,
                    storage_dtype=None,
                    prefetch: bool = False) -> jax.Array:
    """Constant-LHS batched Thomas solve (cuThomasConstantBatch). d: (N, M).

    ``block_n=None`` runs the VMEM-resident kernel (full N per grid step);
    an integer ``block_n`` runs the HBM-streamed split-N kernel pair,
    which lifts the VMEM wall for large N — or, with ``fused=True``, the
    single-call ascend/descend kernel that keeps the intermediate in VMEM
    (half the streamed HBM traffic).  ``storage_dtype="bf16"`` stores the
    factor and RHS streams at bf16 in HBM (fp32 accumulation in-kernel;
    the solve returns fp32).  ``prefetch=True`` double-buffers the chunk
    DMA on hardware (no-op under interpret).  ``transposed=True`` solves
    A^T x = d from the SAME stored factor (the adjoint sweeps)."""
    if interpret is None:
        interpret = default_interpret()
    n = d.shape[0]
    sdt = canonical_storage_dtype(storage_dtype)
    spec = find_spec(3, "constant", streamed=block_n is not None,
                     transposed=transposed, fused=fused)
    _check_spec_vmem(spec, n, block_m, block_n, d.dtype, sdt)
    lhs = stack_tridiag_lhs(f, transposed=transposed)
    d_pad, m = pad_lanes(d, block_m)
    if sdt is not None:
        lhs, d_pad = lhs.astype(sdt), d_pad.astype(sdt)
    if block_n is None:
        x = shared_solver(spec)(lhs, d_pad, block_m=block_m, unroll=unroll,
                                interpret=interpret)
        return x[:, :m]
    lhs, _ = pad_sweep(lhs, block_n, axis=1)
    d_pad, _ = pad_sweep(d_pad, block_n, axis=0)
    x = shared_solver(spec)(lhs, d_pad, block_m=block_m, block_n=block_n,
                            unroll=unroll, interpret=interpret,
                            prefetch=prefetch)
    return x[:n, :m]


def thomas_batch(a, b, c, d, *, block_m: int = 128,
                 block_n: int | None = None, unroll: int = 1,
                 interpret: bool | None = None, fused: bool = False,
                 storage_dtype=None, prefetch: bool = False) -> jax.Array:
    """Per-system-LHS baseline (cuThomasBatch). a/b/c/d: (N, M).

    Dead padded lanes get an IDENTITY main diagonal (b = 1), not the zero
    pad — the fused factorisation would otherwise compute 1/0 and flood
    the padding with inf/NaN (they are sliced off, but they poison
    ``JAX_DEBUG_NANS`` runs and waste the flush-to-zero path).  An integer
    ``block_n`` selects the HBM-streamed split-N pair, which additionally
    identity-pads the main diagonal along the sweep axis for the same
    reason and spills the fused c_hat to HBM between the passes —
    ``fused=True`` keeps the spill in full-N VMEM scratch instead (one
    ascend/descend kernel).  ``storage_dtype="bf16"`` streams the
    diagonals/RHS at bf16 (fp32 in-kernel); ``prefetch=True``
    double-buffers the chunk DMA on hardware."""
    if interpret is None:
        interpret = default_interpret()
    n, m = d.shape
    sdt = canonical_storage_dtype(storage_dtype)
    spec = find_spec(3, "batch", streamed=block_n is not None, fused=fused)
    _check_spec_vmem(spec, n, block_m, block_n, d.dtype, sdt)
    idents = (False, True, False, False)          # b is the main diagonal
    args = [pad_lanes(x, block_m, identity=ident)[0]
            for x, ident in zip((a, b, c, d), idents)]
    if sdt is not None:
        args = [x.astype(sdt) for x in args]
    if block_n is None:
        x = batch_solver(spec)(*args, block_m=block_m, unroll=unroll,
                               interpret=interpret)
        return x[:, :m]
    args = [pad_sweep(x, block_n, axis=0, identity=ident)[0]
            for x, ident in zip(args, idents)]
    x = batch_solver(spec)(*args, block_m=block_m, block_n=block_n,
                           unroll=unroll, interpret=interpret,
                           prefetch=prefetch)
    return x[:n, :m]


def _uniform_eps_param(f: PentaFactor, dtype) -> jax.Array:
    """The all-equal eps value as a (1, 1) ARRAY operand.

    Must stay an array end to end: ``float(f.eps[2])`` on a traced
    ``Factorization`` leaf raises ``ConcretizationTypeError`` under
    ``jax.jit(solve)`` / ``lax.scan`` PDE loops.  Index [2] because the
    factor forces eps[0] = eps[1] = 0 (outside the matrix)."""
    eps = jnp.broadcast_to(jnp.asarray(f.eps), f.beta.shape)
    return eps[2].reshape(1, 1).astype(dtype)


def penta_constant(f: PentaFactor, rhs: jax.Array, *, block_m: int = 128,
                   block_n: int | None = None, unroll: int = 1,
                   interpret: bool | None = None, uniform: bool = False,
                   transposed: bool = False, fused: bool = False,
                   storage_dtype=None, prefetch: bool = False) -> jax.Array:
    """Constant-LHS batched penta solve (cuPentConstantBatch /
    cuPentUniformBatch when ``uniform``).  ``block_n`` selects the
    HBM-streamed split-N kernel pair (``fused=True``: the single-call
    ascend/descend kernel — half the streamed traffic);
    ``storage_dtype="bf16"`` streams the factor/RHS at bf16 (fp32
    in-kernel); ``transposed=True`` solves A^T x = rhs from the SAME
    stored factor."""
    if interpret is None:
        interpret = default_interpret()
    n = rhs.shape[0]
    sdt = canonical_storage_dtype(storage_dtype)
    spec = find_spec(5, "uniform" if uniform else "constant",
                     streamed=block_n is not None, transposed=transposed,
                     fused=fused)
    _check_spec_vmem(spec, n, block_m, block_n, rhs.dtype, sdt)
    eps = _uniform_eps_param(f, sdt or rhs.dtype) if uniform else None
    lhs = stack_penta_lhs(f, uniform=uniform, transposed=transposed)
    rhs_pad, m = pad_lanes(rhs, block_m)
    if sdt is not None:
        lhs, rhs_pad = lhs.astype(sdt), rhs_pad.astype(sdt)
    if block_n is None:
        x = shared_solver(spec)(lhs, rhs_pad, block_m=block_m,
                                unroll=unroll, interpret=interpret, eps=eps)
        return x[:, :m]
    lhs, _ = pad_sweep(lhs, block_n, axis=1)
    rhs_pad, _ = pad_sweep(rhs_pad, block_n, axis=0)
    x = shared_solver(spec)(lhs, rhs_pad, block_m=block_m, block_n=block_n,
                            unroll=unroll, interpret=interpret, eps=eps,
                            prefetch=prefetch)
    return x[:n, :m]


def penta_batch(a, b, c, d, e, rhs, *, block_m: int = 128,
                block_n: int | None = None, unroll: int = 1,
                interpret: bool | None = None, fused: bool = False,
                storage_dtype=None, prefetch: bool = False) -> jax.Array:
    """Per-system-LHS baseline (cuPentBatch).  Identity-pads the MAIN
    diagonal c on the lane axis (and on the sweep axis when streamed):
    dead lanes/rows must factor as identity, not divide by the zero pad.
    ``block_n`` selects the streamed pair (gamma/delta spill to HBM);
    ``fused=True`` keeps the spill in full-N VMEM scratch instead (one
    ascend/descend kernel); ``storage_dtype="bf16"`` streams the
    diagonals/RHS at bf16 (fp32 in-kernel)."""
    if interpret is None:
        interpret = default_interpret()
    n, m = rhs.shape
    sdt = canonical_storage_dtype(storage_dtype)
    spec = find_spec(5, "batch", streamed=block_n is not None, fused=fused)
    _check_spec_vmem(spec, n, block_m, block_n, rhs.dtype, sdt)
    idents = (False, False, True, False, False, False)  # c is the main diag
    args = [pad_lanes(x, block_m, identity=ident)[0]
            for x, ident in zip((a, b, c, d, e, rhs), idents)]
    if sdt is not None:
        args = [x.astype(sdt) for x in args]
    if block_n is None:
        x = batch_solver(spec)(*args, block_m=block_m, unroll=unroll,
                               interpret=interpret)
        return x[:, :m]
    args = [pad_sweep(x, block_n, axis=0, identity=ident)[0]
            for x, ident in zip(args, idents)]
    x = batch_solver(spec)(*args, block_m=block_m, block_n=block_n,
                           unroll=unroll, interpret=interpret,
                           prefetch=prefetch)
    return x[:n, :m]


def recurrence(*operands, h0=None, reverse: bool = False,
               block_m: int = 128, block_n: int | None = None,
               unroll: int = 1, interpret: bool | None = None) -> jax.Array:
    """Gated linear recurrence over an interleaved (N, M) batch.

    ``operands`` is ``(p, q)`` for the order-1 recurrence
    ``h_i = p_i h_{i-1} + q_i`` or ``(s, t, u)`` for the order-2
    ``h_i = s_i h_{i-1} + t_i h_{i-2} + u_i`` — per-token (N, M) gate
    arrays plus the additive operand, the recurrence-layout analogue of
    the batch solvers' per-lane diagonals.  ``reverse=True`` runs from
    i = N-1 down to 0 (carries index i+1/i+2).

    ``h0`` seeds the incoming carries (an array broadcastable over lanes
    for order 1, a ``(h_{-1}, h_{-2})`` pair for order 2).  It is folded
    into the boundary rows of ``q`` ON THE HOST — the kernels keep the
    zero-carry protocol every sweep kernel shares (``reset_carry``), so
    streamed chunking and the zero sweep-padding stay exact: a padded
    gate row multiplies a finite carry by 0.

    ``block_n=None`` runs the VMEM-resident kernel; an integer selects
    the HBM-streamed split-N kernel (a SINGLE kernel, not a pair — a
    recurrence has no back-substitution partner)."""
    if interpret is None:
        interpret = default_interpret()
    *gates, q = (jnp.asarray(x) for x in operands)
    order = len(gates)
    if order not in (1, 2):
        raise ValueError(
            f"recurrence takes (p, q) or (s, t, u); got {order + 1} operands")
    n, m = q.shape
    if h0 is not None:
        hs = (h0,) if order == 1 and not isinstance(h0, (tuple, list)) \
            else tuple(h0)
        if len(hs) != order:
            raise ValueError(f"h0 must carry {order} state(s), got {len(hs)}")
        hs = tuple(jnp.broadcast_to(jnp.asarray(h), q.shape[1:]).astype(
            q.dtype) for h in hs)
        e0 = n - 1 if reverse else 0
        fold = gates[0][e0] * hs[0]
        if order == 2:
            fold = fold + gates[1][e0] * hs[1]
        q = q.at[e0].add(fold)
        if order == 2 and n > 1:
            e1 = n - 2 if reverse else 1
            q = q.at[e1].add(gates[1][e1] * hs[0])
    spec = find_recurrence_spec(order, reverse=reverse,
                                streamed=block_n is not None)
    _check_spec_vmem(spec, n, block_m, block_n, q.dtype)
    args = [pad_lanes(x, block_m)[0] for x in (*gates, q)]
    if block_n is None:
        h = recurrence_solver(spec)(*args, block_m=block_m, unroll=unroll,
                                    interpret=interpret)
        return h[:, :m]
    args = [pad_sweep(x, block_n, axis=0)[0] for x in args]
    h = recurrence_solver(spec)(*args, block_m=block_m, block_n=block_n,
                                unroll=unroll, interpret=interpret)
    return h[:n, :m]


def fused_cn_step(pf: PeriodicTridiagFactor, sigma: float, c: jax.Array, *,
                  block_m: int = 128, unroll: int = 1,
                  interpret: bool | None = None) -> jax.Array:
    """Fused periodic CN diffusion step (beyond-paper; see fused_cn.py)."""
    if interpret is None:
        interpret = default_interpret()
    n = c.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=4,
               itemsize=c.dtype.itemsize)
    lhs = stack_tridiag_lhs(pf.factor)
    z = pf.z.reshape(n, 1)
    params = jnp.zeros((1, 8), c.dtype)
    params = params.at[0, 0].set(sigma).at[0, 1].set(1 - 2 * sigma) \
                   .at[0, 2].set(sigma).at[0, 3].set(pf.v_last) \
                   .at[0, 4].set(pf.inv_denom_sm)
    c_pad, m = pad_lanes(c, block_m)
    x = fused_cn_tridiag_pallas(lhs, z, params, c_pad, block_m=block_m,
                                unroll=unroll, interpret=interpret)
    return x[:, :m]


def fused_cn_penta_step(pf: PeriodicPentaFactor, sigma: float, c: jax.Array,
                        *, block_m: int = 128, unroll: int = 1,
                        interpret: bool | None = None) -> jax.Array:
    """Fused periodic CN hyperdiffusion step (beyond-paper #2;
    see fused_cn_penta.py). c: (N, M) -> (N, M)."""
    if interpret is None:
        interpret = default_interpret()
    n = c.shape[0]
    check_vmem(n, block_m, n_rhs_blocks=2, n_lhs_vecs=10,
               itemsize=c.dtype.itemsize)
    lhs = stack_penta_lhs(pf.factor)
    params = jnp.zeros((1, 16), c.dtype)
    stencil = [-sigma, 4 * sigma, 1 - 6 * sigma, 4 * sigma, -sigma]
    for i, v in enumerate(stencil):
        params = params.at[0, i].set(v)
    for i in range(6):
        params = params.at[0, 5 + i].set(pf.vcoef[i])
    c_pad, m = pad_lanes(c, block_m)
    x = fused_cn_penta_pallas(lhs, pf.Z, pf.Minv, params, c_pad,
                              block_m=block_m, unroll=unroll,
                              interpret=interpret)
    return x[:, :m]


# ---------------------------------------------------------------------------
# Analytic HBM traffic for one solve as dispatched by this module — derived
# from the registered SweepSpec, so every generated variant (transposed,
# batch-streamed, ...) automatically has a roofline entry.
# ---------------------------------------------------------------------------

#: Dispatch entry point per (bandwidth, layout) — the introspection hook
#: behind ``repro.analysis``'s registry-driven sweeps: every REGISTRY spec
#: resolves to exactly one of these public callables, so an analysis (or a
#: sanitizer sweep) can exercise a NEW spec without a hand-kept case list.
ENTRY_POINTS = {
    (3, "shared"): thomas_constant,
    (3, "batch"): thomas_batch,
    (5, "shared"): penta_constant,
    (5, "batch"): penta_batch,
    (1, "recurrence"): recurrence,
    (2, "recurrence"): recurrence,
}


def entry_key(spec) -> tuple:
    """The ``ENTRY_POINTS`` key a registered spec dispatches through —
    sweep specs key on (bandwidth, layout), recurrence specs on
    (order, 'recurrence')."""
    if isinstance(spec, RecurrenceSpec):
        return (spec.order, spec.layout)
    return (spec.bandwidth, spec.layout)


def entry_point(spec):
    """The ops-layer callable that dispatches ``spec`` (see the per-entry
    docstrings for the keyword contract: shared specs take a factor +
    ``transposed``/``uniform`` flags, batch specs take raw diagonals,
    recurrence specs take per-token gate operands + ``reverse``)."""
    return ENTRY_POINTS[entry_key(spec)]


def solver_hbm_traffic_bytes(bandwidth: int, mode: str, n: int, m: int, *,
                             dtype=jnp.float32, streamed: bool = False,
                             transposed: bool = False, fused: bool = False,
                             storage_dtype=None) -> int:
    """Bytes moved HBM<->VMEM by one batched solve of an (n, m) RHS.

    ``fused`` selects the single-call streamed variant's (halved) model;
    ``storage_dtype`` prices the stored-operand streams at that itemsize
    (the bf16 storage path) while intermediates stay at ``dtype``.
    Unknown (bandwidth, mode, streamed, transposed) combinations raise an
    informative ``ValueError`` (via ``find_spec``) naming the valid
    choices."""
    if mode == "batch" and transposed:
        # the adjoint of a batch solve rolls the per-lane diagonals and
        # runs the FORWARD batch kernels — identical streams.
        transposed = False
    spec = find_spec(bandwidth, mode, streamed=streamed,
                     transposed=transposed, fused=fused)
    return spec.traffic_bytes(n, m, dtype,
                              canonical_storage_dtype(storage_dtype))


def recurrence_hbm_traffic_bytes(order: int, n: int, m: int, *,
                                 dtype=jnp.float32, streamed: bool = False,
                                 reverse: bool = False) -> int:
    """Bytes moved HBM<->VMEM by one gated recurrence over an (n, m)
    batch — derived from the registered ``RecurrenceSpec`` exactly like
    the solver model (unknown orders raise via ``find_recurrence_spec``)."""
    spec = find_recurrence_spec(order, reverse=reverse, streamed=streamed)
    return spec.traffic_bytes(n, m, dtype)


def sharded_solver_hbm_traffic_bytes(bandwidth: int, mode: str, n: int,
                                     m: int, n_shards: int, *,
                                     dtype=jnp.float32, streamed: bool = False,
                                     transposed: bool = False,
                                     fused: bool = False,
                                     storage_dtype=None) -> int:
    """PER-DEVICE bytes when the ``sharded`` backend runs this module's
    kernels on each device's local slice of the interleaved batch
    (``repro.solver.sharded`` with engine kernels active).  The solve has
    no collectives, so this IS the single-device model at the local lane
    count (``shard_lanes``) — same ``SweepSpec`` derivation, so the
    sharded x streamed composition can never silently miss the roofline
    table."""
    from .common import shard_lanes
    return solver_hbm_traffic_bytes(bandwidth, mode, n,
                                    shard_lanes(m, n_shards), dtype=dtype,
                                    streamed=streamed, transposed=transposed,
                                    fused=fused, storage_dtype=storage_dtype)


# ---------------------------------------------------------------------------
# Distributed batch solving: one LHS copy per DEVICE, systems sharded.
# ---------------------------------------------------------------------------

def sharded_solve(solve_fn, mesh: Mesh, batch_axes) -> callable:
    """Wrap a (factor, rhs (N, M)) -> x solver so the M axis is sharded over
    ``batch_axes`` of ``mesh`` and the factored LHS is replicated (the
    paper's storage saving, applied per-device). The solve needs no
    collectives — systems are independent.
    """
    from jax.experimental.shard_map import shard_map

    spec_rhs = P(None, batch_axes)
    fn = shard_map(solve_fn, mesh=mesh,
                   in_specs=(P(), spec_rhs), out_specs=spec_rhs,
                   check_rep=False)

    def wrapped(factor, rhs):
        return fn(factor, rhs)

    return wrapped
