"""HBM-streamed (split-N) batched Thomas solve — constant shared LHS.

The resident ``thomas_constant_kernel`` holds the full (N, BLOCK_M) RHS in
VMEM, which caps N at roughly ``VMEM_BUDGET / (2·BLOCK_M·itemsize)``.  This
variant lifts that wall: a 2-D grid ``(M/BLOCK_M, N/BLOCK_N)`` streams
(BLOCK_N, BLOCK_M) chunks through VMEM while the sweep state rides a tiny
``(1, BLOCK_M)`` VMEM scratch that persists across the sequential N-chunk
grid steps (the last grid axis iterates fastest on TPU).

Two kernels — the TPU analogue of the paper's 2-kernel pipeline:

  * ``thomas_streamed_fwd_kernel``  — chunks ascending in N; carries
    ``dh_prev`` and writes the forward-substituted d_hat to HBM.
  * ``thomas_streamed_bwd_kernel``  — chunks *descending* in N (reversed
    index_map); carries ``x_next`` and overwrites d_hat chunks with x.

Boundary rows need no special cases: the carry is zero-initialised on each
lane tile's first chunk, so ``dh_0 = (d_0 − a_0·0)·inv_0`` and
``x_{N−1} = d̂_{N−1} − ĉ_{N−1}·0`` fall out of the general recurrence
(``thomas_factor`` forces a_0 = 0, and ĉ_{N−1} multiplies the zero carry).
For the same reason zero-padding N up to a BLOCK_N multiple is exact and
NaN-free: padded rows compute ``(0 − 0·carry)·0 = 0``.

HBM traffic: 4·N·M + 2·3·N words per solve (the intermediate d̂ makes one
HBM round trip) vs the resident kernel's 2·N·M + 3·N — still well under
the 5·N·M of the per-system-LHS baseline.  See ``hbm_traffic_bytes`` in
``thomas.py``.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (chunk_lhs_spec, chunk_spec, reset_carry, row, scalar,
                     store_row)


def thomas_streamed_fwd_kernel(lhs_ref, d_ref, dh_ref, carry_ref, *,
                               block_n: int, unroll: int):
    """lhs_ref: (3, BLOCK_N) chunk of [a, inv_denom, c_hat];
    d_ref/dh_ref: (BLOCK_N, BLOCK_M); carry_ref: (1, BLOCK_M) = dh_prev."""
    m = d_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))

    def fwd(i, dh_prev):
        dh = (row(d_ref, i, m) - scalar(lhs_ref, 0, i) * dh_prev) \
            * scalar(lhs_ref, 1, i)
        store_row(dh_ref, i, dh)
        return dh

    last = jax.lax.fori_loop(0, block_n, fwd, row(carry_ref, 0, m),
                             unroll=unroll)
    store_row(carry_ref, 0, last)


def thomas_streamed_bwd_kernel(lhs_ref, dh_ref, x_ref, carry_ref, *,
                               block_n: int, unroll: int):
    """Back-substitution over descending chunks; carry_ref holds x_next."""
    m = dh_ref.shape[1]
    reset_carry(carry_ref, pl.program_id(1))

    def bwd(t, x_next):
        i = block_n - 1 - t
        x_i = row(dh_ref, i, m) - scalar(lhs_ref, 2, i) * x_next
        store_row(x_ref, i, x_i)
        return x_i

    first = jax.lax.fori_loop(0, block_n, bwd, row(carry_ref, 0, m),
                              unroll=unroll)
    store_row(carry_ref, 0, first)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "unroll",
                                    "interpret"))
def thomas_constant_streamed_pallas(lhs: jax.Array, d: jax.Array, *,
                                    block_m: int = 128, block_n: int = 512,
                                    unroll: int = 1,
                                    interpret: bool = True) -> jax.Array:
    """lhs: (3, N) stacked [a, inv_denom, c_hat]; d: (N, M).
    Requires N % block_n == 0 and M % block_m == 0 (callers pad)."""
    n, m = d.shape
    num_n = n // block_n
    grid = (m // block_m, num_n)
    carry = [pltpu.VMEM((1, block_m), d.dtype)]

    dh = pl.pallas_call(
        functools.partial(thomas_streamed_fwd_kernel, block_n=block_n,
                          unroll=unroll),
        grid=grid,
        in_specs=[chunk_lhs_spec(3, block_n, num_n),
                  chunk_spec(block_n, block_m, num_n)],
        out_specs=chunk_spec(block_n, block_m, num_n),
        out_shape=jax.ShapeDtypeStruct((n, m), d.dtype),
        scratch_shapes=carry,
        interpret=interpret,
    )(lhs, d)

    return pl.pallas_call(
        functools.partial(thomas_streamed_bwd_kernel, block_n=block_n,
                          unroll=unroll),
        grid=grid,
        in_specs=[chunk_lhs_spec(3, block_n, num_n, reverse=True),
                  chunk_spec(block_n, block_m, num_n, reverse=True)],
        out_specs=chunk_spec(block_n, block_m, num_n, reverse=True),
        out_shape=jax.ShapeDtypeStruct((n, m), d.dtype),
        scratch_shapes=carry,
        interpret=interpret,
    )(lhs, dh)
