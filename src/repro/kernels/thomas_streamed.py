"""HBM-streamed (split-N) batched Thomas solvers — engine spec table.

The resident kernels hold the full (N, BLOCK_M) RHS in VMEM, capping N at
roughly ``VMEM_BUDGET / (2·BLOCK_M·itemsize)``.  The streamed variants
lift that wall: a 2-D grid ``(M/BLOCK_M, N/BLOCK_N)`` streams
(BLOCK_N, BLOCK_M) chunks through VMEM while the sweep state rides a tiny
VMEM scratch that persists across the sequential N-chunk grid steps (the
last grid axis iterates fastest on TPU).  Two kernels — the TPU analogue
of the paper's 2-kernel pipeline: the forward kernel walks chunks
ascending in N and writes the intermediate d_hat to HBM; the backward
kernel walks them descending (reversed index_maps) and overwrites it with
x.  All of that plumbing lives in ``repro.kernels.engine`` now; this
module just names the streamed tridiagonal family:

  * ``thomas_constant_streamed_pallas``   — shared factored LHS.
  * ``thomas_constant_streamed_t_pallas`` — the transposed (adjoint)
    sweeps from the SAME stored factor, so large-N ``grad(solve)`` stays
    off the reference fallback.
  * ``thomas_batch_streamed_pallas``      — per-lane LHS with the fused
    factorisation's c_hat scratch SPILLED to HBM between the two passes
    (DESIGN.md §2.2), lifting the VMEM wall for ``mode="batch"`` too.

Boundary rows need no special cases: carries zero-init on each lane
tile's first chunk, so the first/last rows fall out of the general
recurrence.  Zero sweep-padding is exact for the factored kernels
(``(0 - 0·carry)·0 = 0``); the batch kernels divide in-kernel, so their
MAIN diagonal identity-pads along N as well as along the lanes
(``common.pad_sweep(identity=True)``).
"""

from __future__ import annotations

from .engine import REGISTRY, batch_solver, shared_solver

thomas_constant_streamed_pallas = shared_solver(
    REGISTRY["thomas_constant_streamed"])
thomas_constant_streamed_t_pallas = shared_solver(
    REGISTRY["thomas_constant_streamed_t"])
thomas_batch_streamed_pallas = batch_solver(
    REGISTRY["thomas_batch_streamed"])
