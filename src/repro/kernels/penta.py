"""Pallas TPU kernels: batched pentadiagonal LR solves (paper §IV).

cuPentConstantBatch -> ``penta_constant_kernel``: shared (5, N) factored LHS
[eps, beta, inv_alpha, gamma, delta] in one VMEM-resident block; interleaved
(N, BLOCK_M) RHS, one system per lane.

cuPentBatch (baseline) -> ``penta_batch_kernel``: five (N, BLOCK_M) per-lane
diagonal blocks, factorisation fused into every solve.

cuPentUniformBatch -> constant kernel with a (4, N) LHS: all diagonal
entries equal (paper §IV.C), so the eps vector degenerates to one value,
saving the eps vector fetch.  eps rides in as a (1, 1) ARRAY operand — not
a Python float closed over by the kernel — so a traced ``Factorization``
leaf can feed it and ``jax.jit(solve)`` never hits a
``ConcretizationTypeError``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import row, scalar, store_row

# row indices in the stacked constant LHS
EPS, BETA, INV_ALPHA, GAMMA, DELTA = range(5)


def penta_constant_kernel(*refs, n: int, unroll: int, uniform: bool = False):
    """refs: [eps_ref (1, 1) when uniform,] lhs_ref ((5, N), or (4, N) when
    uniform — the eps row is dropped), f_ref/x_ref: (N, BLOCK_M)."""
    if uniform:
        eps_ref, lhs_ref, f_ref, x_ref = refs
        off = -1  # uniform LHS drops the eps row
        eps_at = lambda i: eps_ref[0, 0]
    else:
        lhs_ref, f_ref, x_ref = refs
        off = 0
        eps_at = lambda i: scalar(lhs_ref, EPS, i)
    m = f_ref.shape[1]

    # --- forward:  g_i = (f_i - eps_i g_{i-2} - beta_i g_{i-1}) inv_alpha_i
    g0 = row(f_ref, 0, m) * scalar(lhs_ref, INV_ALPHA + off, 0)
    store_row(x_ref, 0, g0)
    g1 = (row(f_ref, 1, m) - scalar(lhs_ref, BETA + off, 1) * g0) \
        * scalar(lhs_ref, INV_ALPHA + off, 1)
    store_row(x_ref, 1, g1)

    def fwd(i, carry):
        gm1, gm2 = carry
        g = (row(f_ref, i, m) - eps_at(i) * gm2
             - scalar(lhs_ref, BETA + off, i) * gm1) \
            * scalar(lhs_ref, INV_ALPHA + off, i)
        store_row(x_ref, i, g)
        return g, gm1

    gN1, gN2 = jax.lax.fori_loop(2, n, fwd, (g1, g0), unroll=unroll)

    # --- backward: x_i = g_i - gamma_i x_{i+1} - delta_i x_{i+2}
    x_last = gN1                           # x_{N-1} = g_{N-1}
    x_prev = gN2 - scalar(lhs_ref, GAMMA + off, n - 2) * x_last
    store_row(x_ref, n - 2, x_prev)

    def bwd(k, carry):
        xp1, xp2 = carry
        i = n - 3 - k
        x_i = (row(x_ref, i, m)
               - scalar(lhs_ref, GAMMA + off, i) * xp1
               - scalar(lhs_ref, DELTA + off, i) * xp2)
        store_row(x_ref, i, x_i)
        return x_i, xp1

    jax.lax.fori_loop(0, n - 2, bwd, (x_prev, x_last), unroll=unroll)


def penta_batch_kernel(a_ref, b_ref, c_ref, d_ref, e_ref, f_ref, x_ref,
                       gam_ref, del_ref, *, n: int, unroll: int):
    """Per-system LHS baseline with fused factorisation (cuPentBatch)."""
    m = f_ref.shape[1]
    zero = jnp.zeros((m,), f_ref.dtype)

    # factorisation + forward sweep interleaved (single pass over rows)
    # carries: gamma_{i-1}, gamma_{i-2}, delta_{i-1}, delta_{i-2}, g_{i-1}, g_{i-2}
    def body(i, carry):
        g1, g2, dl1, dl2, gg1, gg2 = carry
        a_i = row(a_ref, i, m)
        beta_i = row(b_ref, i, m) - a_i * g2
        alpha_i = row(c_ref, i, m) - a_i * dl2 - beta_i * g1
        inv = 1.0 / alpha_i
        gamma_i = (row(d_ref, i, m) - beta_i * dl1) * inv
        delta_i = row(e_ref, i, m) * inv
        store_row(gam_ref, i, gamma_i)
        store_row(del_ref, i, delta_i)
        g_i = (row(f_ref, i, m) - a_i * gg2 - beta_i * gg1) * inv
        store_row(x_ref, i, g_i)
        return gamma_i, g1, delta_i, dl1, g_i, gg1

    # i = 0 (a_0 = b_0 = 0 outside matrix)
    inv0 = 1.0 / row(c_ref, 0, m)
    gamma0 = row(d_ref, 0, m) * inv0
    delta0 = row(e_ref, 0, m) * inv0
    store_row(gam_ref, 0, gamma0)
    store_row(del_ref, 0, delta0)
    g0 = row(f_ref, 0, m) * inv0
    store_row(x_ref, 0, g0)
    # i = 1 (a_1 = 0)
    beta1 = row(b_ref, 1, m)
    inv1 = 1.0 / (row(c_ref, 1, m) - beta1 * gamma0)
    gamma1 = (row(d_ref, 1, m) - beta1 * delta0) * inv1
    delta1 = row(e_ref, 1, m) * inv1
    store_row(gam_ref, 1, gamma1)
    store_row(del_ref, 1, delta1)
    g1 = (row(f_ref, 1, m) - beta1 * g0) * inv1
    store_row(x_ref, 1, g1)

    carry = (gamma1, gamma0, delta1, delta0, g1, g0)
    _, _, _, _, gN1, gN2 = jax.lax.fori_loop(2, n, body, carry, unroll=unroll)

    # backward
    x_last = gN1
    x_prev = gN2 - row(gam_ref, n - 2, m) * x_last
    store_row(x_ref, n - 2, x_prev)

    def bwd(k, carry):
        xp1, xp2 = carry
        i = n - 3 - k
        x_i = (row(x_ref, i, m) - row(gam_ref, i, m) * xp1
               - row(del_ref, i, m) * xp2)
        store_row(x_ref, i, x_i)
        return x_i, xp1

    jax.lax.fori_loop(0, n - 2, bwd, (x_prev, x_last), unroll=unroll)


def _col_spec(n, block_m):
    return pl.BlockSpec((n, block_m), lambda j: (0, j))


@functools.partial(jax.jit,
                   static_argnames=("block_m", "unroll", "interpret", "uniform"))
def penta_constant_pallas(lhs: jax.Array, f: jax.Array, *, block_m: int = 128,
                          unroll: int = 1, interpret: bool = True,
                          uniform: bool = False,
                          eps: jax.Array | None = None) -> jax.Array:
    """lhs: (5, N) [eps, beta, inv_alpha, gamma, delta] ((4, N) when
    ``uniform`` — the cuPentUniformBatch variant, with ``eps`` supplied as
    a (1, 1) array operand); f: (N, M)."""
    n, m = f.shape
    rows = 4 if uniform else 5
    in_specs = [pl.BlockSpec((rows, n), lambda j: (0, 0)),
                _col_spec(n, block_m)]
    args = [lhs, f]
    if uniform:
        in_specs.insert(0, pl.BlockSpec((1, 1), lambda j: (0, 0)))
        args.insert(0, eps)
    return pl.pallas_call(
        functools.partial(penta_constant_kernel, n=n, unroll=unroll,
                          uniform=uniform),
        grid=(m // block_m,),
        in_specs=in_specs,
        out_specs=_col_spec(n, block_m),
        out_shape=jax.ShapeDtypeStruct((n, m), f.dtype),
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit, static_argnames=("block_m", "unroll", "interpret"))
def penta_batch_pallas(a, b, c, d, e, f, *, block_m: int = 128,
                       unroll: int = 1, interpret: bool = True) -> jax.Array:
    n, m = f.shape
    spec = _col_spec(n, block_m)
    return pl.pallas_call(
        functools.partial(penta_batch_kernel, n=n, unroll=unroll),
        grid=(m // block_m,),
        in_specs=[spec] * 6,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, m), f.dtype),
        scratch_shapes=[pltpu.VMEM((n, block_m), f.dtype),
                        pltpu.VMEM((n, block_m), f.dtype)],
        interpret=interpret,
    )(a, b, c, d, e, f)


def hbm_traffic_bytes(n: int, m: int, dtype=jnp.float32) -> dict:
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "constant": (n * m * 2 + 5 * n) * itemsize,
        "uniform": (n * m * 2 + 4 * n + 1) * itemsize,
        "batch": (n * m * 7) * itemsize,  # 5 diagonals + RHS in, x out
        # streamed (split-N): the intermediate g makes one HBM round trip
        # (fwd writes it, bwd reads it) and both passes re-stream the LHS.
        "constant_streamed": (n * m * 4 + 2 * 5 * n) * itemsize,
        "uniform_streamed": (n * m * 4 + 2 * 4 * n + 1) * itemsize,
    }
