from .optimizer import AdamW, apply_updates, global_norm, warmup_cosine
from .train_loop import (
    batch_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_step_shardings,
)

__all__ = ["AdamW", "apply_updates", "batch_shardings", "global_norm",
           "make_decode_step", "make_prefill_step", "make_train_step",
           "train_step_shardings", "warmup_cosine"]
