"""train_step / serve_step factories with full sharding annotations.

``make_train_step`` returns a function suitable both for real execution
(jitted, donated buffers) and for the multi-pod dry-run (``.lower()`` against
ShapeDtypeStructs). Gradient accumulation over microbatches is a
``lax.scan`` (constant HLO size).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import Model
from repro.sharding import ShardingCtx
from .optimizer import AdamW, apply_updates


def batch_shardings(sctx: ShardingCtx, batch_specs: dict):
    """NamedShardings for a batch dict of ShapeDtypeStructs."""
    def one(s):
        if s.ndim == 1:
            return sctx.sharding(("act_batch",), s.shape)
        if s.ndim == 0:
            return sctx.sharding((), s.shape)
        names = ("act_batch",) + (None,) * (s.ndim - 1)
        return sctx.sharding(names, s.shape)
    return jax.tree_util.tree_map(one, batch_specs)


def cache_shardings(sctx: ShardingCtx, cache_spec_tree):
    return sctx.tree_shardings(cache_spec_tree)


def make_train_step(model: Model, sctx: ShardingCtx, opt: AdamW,
                    *, accum: int = 1, constrain_grads: bool = False):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``constrain_grads`` pins each gradient to its parameter's sharding right
    after value_and_grad — an explicit hint that lets the SPMD partitioner
    reduce-scatter partial gradients instead of all-reducing them (§Perf
    iteration; off by default = the measured baseline).
    """
    grad_shardings = None
    if constrain_grads:
        grad_shardings = sctx.tree_shardings(model.param_specs())

    def loss_fn(params, batch):
        return model.loss(params, batch, sctx)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if grad_shardings is not None:
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, step):
        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, _, g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None
            # split the leading batch dim into microbatches
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            gz = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (gz, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        deltas, opt_state, opt_metrics = opt.update(grads, opt_state, params,
                                                    step)
        params = apply_updates(params, deltas)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_prefill_step(model: Model, sctx: ShardingCtx):
    def prefill_step(params, batch):
        return model.prefill(params, batch, sctx)
    return prefill_step


def make_decode_step(model: Model, sctx: ShardingCtx):
    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos, sctx)
    return decode_step


def train_step_shardings(model: Model, sctx: ShardingCtx, opt: AdamW,
                         batch_specs: dict):
    """(in_shardings, out_shardings) pytrees for jit/lower of train_step."""
    pspecs = model.param_specs()
    p_sh = sctx.tree_shardings(pspecs)
    o_sh = sctx.tree_shardings(opt.state_specs(pspecs))
    b_sh = batch_shardings(sctx, batch_specs)
    step_sh = sctx.sharding((), ())
    in_sh = (p_sh, o_sh, b_sh, step_sh)
    out_sh = (p_sh, o_sh, None)   # metrics unannotated (replicated scalars)
    return in_sh, out_sh
