"""Sharded AdamW with configurable moment dtype + warmup-cosine schedule.

Optimizer state inherits the parameter sharding (ZeRO-3 style: both are
sharded over data AND model axes via the logical rules), so 1T-param configs
fit 512 chips. ``opt_dtype="bfloat16"`` halves moment memory (kimi-k2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return f


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable                 # step -> learning rate
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0
    opt_dtype: Any = jnp.float32

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.opt_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def state_specs(self, param_specs):
        """Spec tree for the optimizer state (same logical names as params)."""
        def conv(s: ParamSpec) -> ParamSpec:
            return ParamSpec(s.shape, s.names, self.opt_dtype, init="zeros")
        one = jax.tree_util.tree_map(conv, param_specs,
                                     is_leaf=lambda x: isinstance(x, ParamSpec))
        return {"m": one, "v": jax.tree_util.tree_map(lambda s: s, one)}

    def update(self, grads, state, params, step):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip / (gnorm + 1e-9))
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = self.lr(step)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m_new / bc1
            vhat = v_new / bc2
            step_dir = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = -lr * (step_dir + self.weight_decay * p.astype(jnp.float32))
            return (delta.astype(p.dtype), m_new.astype(self.opt_dtype),
                    v_new.astype(self.opt_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        deltas = treedef.unflatten([o[0] for o in out])
        new_state = {
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
        }
        return deltas, new_state, {"grad_norm": gnorm, "lr": lr}


def apply_updates(params, deltas):
    return jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype),
                                  params, deltas)
