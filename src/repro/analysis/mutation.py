"""Mutation self-test: prove the analyzer actually catches defects.

A static checker that never fires is indistinguishable from one that
works.  This module seeds one representative defect per class the
analyzer claims to cover — by patching the REAL pass tables, index-map
builders, carry reset, eps plumbing and traffic model in place — and
asserts the corresponding checker reports it.  Each mutation is applied
inside a context manager and fully reverted; the generated solvers are
``lru_cache``-d *jit wrappers* whose bodies re-read the module globals on
every (un-jitted) re-execution, so the capture layer sees the mutated
world without any cache invalidation.

Defect classes (the known failure modes of this codebase's history and
of the CUDA solvers the paper benchmarks):

  1. **swapped subtraction order** — reversing the forward-pass terms of
     the penta sweep keeps the math "correct" in exact arithmetic but
     breaks the bit-exactness contract; ``speccheck`` flags the
     non-canonical order.
  2. **off-by-one index map** — a ``chunk_spec`` that maps grid point
     ``k`` to block ``k + 1``; Pallas would clamp and silently corrupt.
     ``gridcheck`` flags blocks outside the range and block 0 never
     written.
  3. **dropped reset_carry** — the k == 0 zero-init removed; lane tile
     j+1 inherits tile j's final sweep state.  ``gridcheck``'s mock
     execution flags the cross-lane-tile carry race.
  4. **baked float(eps)** — concretizing the uniform eps operand; breaks
     ``jax.jit(solve)`` with a traced Factorization.  Caught twice:
     ``tracecheck`` (eval_shape with abstract leaves) and the AST lint
     on the mutated source text.
  5. **stale traffic constant** — ``traffic_words`` drifting from what
     the builders actually stream; ``speccheck``'s independent recount
     flags the exact word delta.
  6. **swapped gate lags** — the order-2 recurrence pass wiring the
     lag-1 carry to the second-gate operand and vice versa; parity tests
     at order 1 never see it and symmetric test data can mask it.
     ``speccheck``'s structural check on the gate-operand pass table
     flags the miswired lag.
  7. **forgotten descend mirror** — the fused single-call kernels' output
     index map using the ascend-phase walk for the descend phase too;
     every descend grid point then clamps onto the last chunk (Pallas
     never errors) and the back-substitution silently overwrites one
     block ``num_n`` times.  ``gridcheck``'s fused walk/coverage checks
     flag the missing mirror.
"""

from __future__ import annotations

import pathlib
import contextlib
import dataclasses

import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import engine, ops

from . import Finding
from . import lint, gridcheck, speccheck, tracecheck


@dataclasses.dataclass(frozen=True)
class MutationResult:
    name: str
    detected: bool
    evidence: tuple  # the matching Finding(s), empty when undetected


# ---------------------------------------------------------------------------
# The seeded defects
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def _swapped_subtraction_order():
    key = (5, False, False)
    fwd, bwd = engine._PASS_TABLE[key]
    engine._PASS_TABLE[key] = (
        engine.PassSpec(tuple(reversed(fwd.terms)), fwd.scale), bwd)
    try:
        yield
    finally:
        engine._PASS_TABLE[key] = (fwd, bwd)


@contextlib.contextmanager
def _off_by_one_index_map():
    orig = engine.chunk_spec

    def bad(block_n, block_m, num_n, *, reverse=False):
        if reverse:
            return orig(block_n, block_m, num_n, reverse=True)
        return pl.BlockSpec((block_n, block_m), lambda j, k: (k + 1, j))

    engine.chunk_spec = bad
    try:
        yield
    finally:
        engine.chunk_spec = orig


@contextlib.contextmanager
def _dropped_reset_carry():
    orig = engine.reset_carry
    engine.reset_carry = lambda carry_ref, k: None
    try:
        yield
    finally:
        engine.reset_carry = orig


@contextlib.contextmanager
def _baked_float_eps():
    orig = ops._uniform_eps_param

    def bad(f, dtype):
        eps = jnp.broadcast_to(jnp.asarray(f.eps), f.beta.shape)
        return jnp.full((1, 1), float(eps[2]), dtype)

    ops._uniform_eps_param = bad
    try:
        yield
    finally:
        ops._uniform_eps_param = orig


@contextlib.contextmanager
def _swapped_gate_lags():
    orig = engine._RECUR_TABLE[2]
    engine._RECUR_TABLE[2] = engine.PassSpec(((1, 1), (0, 2)), None)
    try:
        yield
    finally:
        engine._RECUR_TABLE[2] = orig


@contextlib.contextmanager
def _forgotten_descend_mirror():
    orig = engine.fused_chunk_spec

    def bad(block_n, block_m, num_n, *, phase):
        return orig(block_n, block_m, num_n,
                    phase="ascend" if phase == "descend" else phase)

    engine.fused_chunk_spec = bad
    try:
        yield
    finally:
        engine.fused_chunk_spec = orig


@contextlib.contextmanager
def _stale_traffic_constant():
    orig = engine.SweepSpec.traffic_words

    def bad(self, n, m):
        return orig(self, n, m) + n * m

    engine.SweepSpec.traffic_words = bad
    try:
        yield
    finally:
        engine.SweepSpec.traffic_words = orig


# ---------------------------------------------------------------------------
# Per-class detection probes
# ---------------------------------------------------------------------------

def _trace_uniform_penta() -> list:
    """tracecheck restricted to the cells the eps mutation can reach."""
    out: list = []
    for case in tracecheck.contract_cases():
        if case[1] == 5 and case[2] == "uniform":
            out.extend(tracecheck.check_case(*case))
    return out


def _lint_mutated_ops() -> list:
    """AST-lint the eps mutation at the source level: rewrite the real
    ops.py text to the baked-float form and lint the result."""
    src = pathlib.Path(ops.__file__).read_text()
    mutated = src.replace("eps[2].reshape(1, 1).astype(dtype)",
                          "jnp.asarray(float(eps[2]), dtype).reshape(1, 1)")
    if mutated == src:
        return [Finding("mutation", "ops.py",
                        "eps site not found — the source-level mutation "
                        "no longer applies; update mutation.py")]
    findings = lint.lint_source(mutated, "ops.py(mutated)")
    if not findings:
        return []
    return findings


def _float_eps_probe() -> list:
    """Both detection layers for defect class 4 must fire."""
    traced = _trace_uniform_penta()
    linted = _lint_mutated_ops()
    if any(f.checker == "mutation" for f in linted):
        return linted  # the mutation itself is broken — surface that
    if not traced or not linted:
        return []  # one layer missed -> undetected
    return traced + linted


_MUTATIONS = (
    ("swapped-subtraction-order", _swapped_subtraction_order,
     speccheck.run, "subtraction order"),
    ("index-map-off-by-one", _off_by_one_index_map,
     gridcheck.run, "outside the block range"),
    ("dropped-reset-carry", _dropped_reset_carry,
     gridcheck.run, "carry race"),
    ("baked-float-eps", _baked_float_eps,
     _float_eps_probe, ""),
    ("stale-traffic-constant", _stale_traffic_constant,
     speccheck.run, "HBM traffic drift"),
    ("swapped-gate-lags", _swapped_gate_lags,
     speccheck.run, "gate operand"),
    ("forgotten-descend-mirror", _forgotten_descend_mirror,
     gridcheck.run, "mirror"),
)


def self_test(verbose: bool = False) -> list:
    """Run every seeded defect; returns one MutationResult per class."""
    import jax

    results = []
    for name, mutate, probe, match in _MUTATIONS:
        # the probes re-trace mutated call paths; a clean trace cached by
        # an earlier run would mask the defect (and a mutated one would
        # leak out), so the cache is dropped on both sides
        jax.clear_caches()
        with mutate():
            findings = probe()
        jax.clear_caches()
        hits = tuple(f for f in findings if match in f.message)
        results.append(MutationResult(name, bool(hits), hits))
        if verbose:
            mark = "caught" if hits else "MISSED"
            print(f"  {name:28s} {mark} "
                  f"({len(hits)} finding(s))")
    return results
