"""Abstract interpretation of the kernel builders: capture, don't run.

The engine's builders (``shared_solver`` / ``batch_solver``) are ordinary
Python that ends in ``pl.pallas_call(...)``.  Everything the static
checkers need — the grid, every ``BlockSpec`` index map, the scratch
shapes, which operands feed which pass — is fully determined at trace
time, before any kernel body executes.  So the capture layer swaps
``pl.pallas_call`` for a recorder that logs the call and returns
zero-filled outputs of the declared ``out_shape``, then drives the
UNJITTED builder entry point (``solver.__wrapped__``) on
``SweepSpec.dummy_args``.  No Pallas kernel ever runs; the records are
the kernels' complete stream structure.

From the records two independent recounts are derived:

  * ``recount_traffic_words`` — HBM<->VMEM words, counted as *distinct
    blocks touched* per operand per ``pallas_call`` (compulsory traffic:
    a constant index map keeps its block resident, a chunked map streams
    each chunk once).  ``(1, 1)`` blocks are broadcast scalar parameters
    (the uniform eps) and are counted once per solve, deduplicated by
    buffer identity across the pass pair — matching the model's ``+ eps``
    convention.
  * ``recount_vmem_counts`` — the per-grid-step working set
    ``(n_rhs_blocks, n_lhs_vecs, n_carry_rows, n_sweep_scratch)``,
    classified from block shapes: lane-tiled blocks (minor dim ==
    block_m, including lane-tiled VMEM scratch) are RHS-class blocks,
    ``(rows, N-extent)`` blocks are the stacked shared LHS, small
    ``(c, block_m)`` scratch rows are the streamed sweep carries, and
    lane-tiled scratch spanning the FULL output N extent is the fused
    kernels' resident intermediate (``SweepSpec.sweep_scratch``).  The
    streamed pair reports the elementwise max over its two kernels (the
    forward's larger set — exactly what the budget check reasons with).

Both recounts are cross-checked in ``speccheck`` against the numbers
``SweepSpec`` *derives* (``traffic_words`` / ``vmem_counts``): the model
and the code can only drift together or not at all.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math

import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import block_shape_of, index_map_of
from repro.kernels.engine import (SweepSpec, batch_solver, recurrence_solver,
                                  shared_solver)

#: Reference shapes the checkers trace at — small enough to enumerate the
#: grid exhaustively, ragged-free (the builders require padded operands),
#: and chosen so the three block classes cannot collide: the lane tile
#: (8) differs from the N-chunk (16), the full sweep (48), and any carry
#: row count (<= 6).
TRACE_N, TRACE_M = 48, 24
TRACE_BLOCK_M, TRACE_BLOCK_N = 8, 16


@dataclasses.dataclass(frozen=True)
class CallRecord:
    """One captured ``pl.pallas_call``: its grid, specs, and operands."""

    kernel: object        # the kernel body (a functools.partial)
    grid: tuple
    in_specs: tuple       # BlockSpec per operand
    out_specs: tuple      # BlockSpec per output
    out_shapes: tuple     # ShapeDtypeStruct per output
    scratch_shapes: tuple # MemoryRef per scratch operand
    arg_ids: tuple        # id() of each operand buffer (scalar-param dedupe)
    arg_shapes: tuple

    def grid_points(self) -> list:
        return list(itertools.product(*(range(g) for g in self.grid)))

    def blocks_of(self, spec, shape=None) -> set:
        """Distinct block-index tuples ``spec`` touches over the grid."""
        index_map = index_map_of(spec)
        return {tuple(index_map(*pt)) for pt in self.grid_points()}


@contextlib.contextmanager
def capture_pallas_calls():
    """Swap ``pl.pallas_call`` for a recorder; yields the record list.

    The recorder returns zero-filled arrays of the declared ``out_shape``
    so multi-call builders (streamed pairs feeding the mid result into
    the second call) keep composing.  Single-threaded use only — the
    patch is process-global while the context is open.
    """
    records = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid, in_specs, out_specs, out_shape,
                         scratch_shapes=(), **_kwargs):
        multi = isinstance(out_shape, (list, tuple))
        outs = tuple(out_shape) if multi else (out_shape,)
        ospecs = (tuple(out_specs) if isinstance(out_specs, (list, tuple))
                  else (out_specs,))

        def runner(*args):
            records.append(CallRecord(
                kernel=kernel, grid=tuple(grid),
                in_specs=tuple(in_specs), out_specs=ospecs, out_shapes=outs,
                scratch_shapes=tuple(scratch_shapes),
                arg_ids=tuple(id(a) for a in args),
                arg_shapes=tuple(tuple(a.shape) for a in args)))
            res = [jnp.zeros(o.shape, o.dtype) for o in outs]
            return res if multi else res[0]

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield records
    finally:
        pl.pallas_call = real


def trace_spec_calls(spec, *, n: int = TRACE_N, m: int = TRACE_M,
                     block_m: int = TRACE_BLOCK_M,
                     block_n: int = TRACE_BLOCK_N) -> list:
    """Drive ``spec``'s builder on dummy operands, returning the captured
    ``CallRecord`` list — ``spec.num_pallas_calls`` records: one for
    resident variants and for every recurrence (single-pass), the
    forward/backward pair for streamed sweeps."""
    assert m % block_m == 0 and n % block_n == 0
    args, eps = spec.dummy_args(n, m)
    kwargs = dict(block_m=block_m, interpret=True)
    if spec.streamed:
        kwargs["block_n"] = block_n
    if getattr(spec, "uniform", False):
        kwargs["eps"] = eps
    builder = {"shared": shared_solver, "batch": batch_solver,
               "recurrence": recurrence_solver}[spec.layout]
    # .__wrapped__ bypasses jax.jit: the builder body re-executes on every
    # call, so the capture sees the pallas_calls even for cached specs.
    with capture_pallas_calls() as records:
        builder(spec).__wrapped__(*args, **kwargs)
    return records


def _is_scalar_param(shape: tuple) -> bool:
    """(1, 1) blocks are broadcast scalar parameters (the uniform eps)."""
    return math.prod(shape) == 1


def recount_traffic_words(records: list) -> int:
    """Independent HBM traffic recount (words) from the captured calls."""
    words = 0
    seen_params = set()
    for rec in records:
        for spec_, buf in zip(rec.in_specs, rec.arg_ids):
            shape = block_shape_of(spec_)
            if _is_scalar_param(shape):
                if buf not in seen_params:
                    seen_params.add(buf)
                    words += 1
                continue
            words += len(rec.blocks_of(spec_)) * math.prod(shape)
        for spec_ in rec.out_specs:
            shape = block_shape_of(spec_)
            words += len(rec.blocks_of(spec_)) * math.prod(shape)
    return words


def recount_vmem_counts(records: list, *, block_m: int = TRACE_BLOCK_M
                        ) -> tuple:
    """Independent ``(n_rhs_blocks, n_lhs_vecs, n_carry_rows,
    n_sweep_scratch)`` recount — the elementwise max over the captured
    kernels' per-grid-step sets.

    The fourth slot counts the FUSED kernels' full-N VMEM intermediates
    (``SweepSpec.sweep_scratch``): lane-tiled scratch whose N extent
    matches the full output sweep rather than a streamed chunk — zero for
    every resident / two-call / recurrence kernel."""
    counts = (0, 0, 0, 0)
    for rec in records:
        blocks = lhs = carry = sweep = 0
        sweep_extents = set()
        n_extents = {tuple(o.shape)[0] for o in rec.out_shapes}
        for spec_ in tuple(rec.in_specs) + tuple(rec.out_specs):
            shape = block_shape_of(spec_)
            if _is_scalar_param(shape):
                continue
            if shape[-1] == block_m:
                blocks += 1
                sweep_extents.add(shape[0])
            else:
                lhs += shape[0]
        for scratch in rec.scratch_shapes:
            shape = tuple(scratch.shape)
            if shape[0] in sweep_extents:
                blocks += 1          # lane-tiled full-sweep scratch
            elif shape[-1] == block_m and shape[0] in n_extents:
                sweep += 1           # fused full-N intermediate scratch
            else:
                carry += shape[0]    # streamed carry rows
        counts = tuple(max(a, b)
                       for a, b in zip(counts, (blocks, lhs, carry, sweep)))
    return counts
