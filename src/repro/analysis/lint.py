"""AST lint: no concretization of potentially-traced values.

``float(x)`` / ``int(x)`` / ``x.item()`` / ``np.asarray(x)`` on a traced
JAX value raise ``ConcretizationTypeError`` — but only when the enclosing
function is finally jitted, which for solver code can be several PRs
after the line lands (PR 3's ``float(f.eps[2])`` shipped green and broke
``jax.jit(solve)`` later).  This lint flags those calls *statically* in
the kernel/solver layers, where nearly every value is potentially traced.

Legitimate host-side sites (static shapes, mesh extents, checkpoint
bookkeeping) carry an explicit allowlist marker on the flagged line::

    n_bytes = int(np.prod(leaf.shape))  # speclint: allow-concretize

The marker is a deliberate audit trail: every concretization in the
traced layers is either provably host-side (and says so) or a finding.
Calls whose argument is a literal constant are not flagged.
"""

from __future__ import annotations

import ast
import pathlib

from . import Finding

#: The marker that allowlists one line (put it on the line of the call).
ALLOW_MARKER = "speclint: allow-concretize"

#: Directories under src/repro whose code runs inside traces.  models and
#: core joined when the sequence models moved onto the Pallas recurrence
#: engine: their forward passes now sit inside jit/scan the same way the
#: solver layers do.
TRACED_PACKAGES = ("kernels", "solver", "models", "core")

_CAST_NAMES = ("float", "int")
_NUMPY_NAMES = ("np", "numpy")


def _is_static_arg(node) -> bool:
    """Literal constants (and unary +/- of them) can never be traced."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.operand,
                                                    ast.Constant):
        return True
    return False


def _flag_of(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Name) and fn.id in _CAST_NAMES:
        if node.args and not _is_static_arg(node.args[0]):
            return f"{fn.id}(...)"
        return None
    if isinstance(fn, ast.Attribute):
        if fn.attr == "item" and not node.args:
            return ".item()"
        if fn.attr == "asarray" and isinstance(fn.value, ast.Name) and \
                fn.value.id in _NUMPY_NAMES:
            return "np.asarray(...)"
    return None


def lint_source(text: str, filename: str = "<string>") -> list:
    """Lint one source text; returns findings."""
    out: list = []
    try:
        tree = ast.parse(text, filename=filename)
    except SyntaxError as exc:
        return [Finding("astlint", f"{filename}:{exc.lineno}",
                        f"syntax error: {exc.msg}")]
    lines = text.splitlines()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        flag = _flag_of(node)
        if flag is None:
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if ALLOW_MARKER in line:
            continue
        out.append(Finding(
            "astlint", f"{filename}:{node.lineno}",
            f"{flag} concretizes a potentially-traced value (raises "
            f"ConcretizationTypeError under jit/scan); hoist it to the "
            f"host side or mark the line with '# {ALLOW_MARKER}'"))
    return out


def run(root: str | None = None) -> list:
    """Lint every module of the traced packages."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]
    root = pathlib.Path(root)
    out: list = []
    for pkg in TRACED_PACKAGES:
        for path in sorted((root / pkg).rglob("*.py")):
            rel = path.relative_to(root.parent)
            out.extend(lint_source(path.read_text(), str(rel)))
    return out
