"""gridcheck — prove the streamed 2-D grid's index maps and carry protocol.

The streamed (split-N) kernels run on a grid ``(M/block_m, N/block_n)``
whose LAST axis iterates fastest: for each lane tile the N-chunks execute
sequentially and the sweep state rides a small VMEM scratch between them.
Three things can silently go wrong, and none of them is caught by shape
checking: a write map that misses (or doubles) a block, a backward walk
that does not exactly reverse the forward one, and a carry scratch that
is not reset when the grid wraps to the next lane tile (a cross-lane-tile
carry RACE: tile j+1's first chunk starts from tile j's final sweep
state).  Pallas clamps out-of-range block indices instead of failing, so
an off-by-one index map produces wrong *values*, never an error.

This checker proves all three per registered streamed spec, statically:

  * **write coverage** — enumerating every output ``BlockSpec`` index map
    over the whole grid must hit every block of the output exactly once
    (a bijection onto the block range);
  * **read bounds + mirror** — every chunked input stays inside its
    operand's block range, and within each kernel all N-chunked walks
    agree on one direction: ascending ``0..num_n-1`` in the forward
    kernel, the exact reversal ``num_n-1..0`` in the backward kernel;
  * **carry protocol** — the kernel body is executed OUTSIDE Pallas on
    mock refs (``jax.lax.fori_loop`` / ``pl.when`` / ``pl.program_id``
    swapped for host equivalents), twice per probe: once with a
    zero-filled carry scratch and once with a sentinel-filled one.  At
    ``k == 0`` the outputs must be identical (stale state is dead — the
    ``reset_carry`` contract); at ``k > 0`` they must differ (the carry
    actually threads the sweep across chunks — a kernel that always
    resets is equally wrong).

The mock execution is the "abstract interpretation of the kernel
builders" leg of the tentpole: it runs the *generated* bodies — the same
``functools.partial`` objects ``pl.pallas_call`` would receive — with the
grid made explicit, so a defect in the generic builders (not just the
tables) is caught before anything touches a TPU.
"""

from __future__ import annotations

import contextlib

import jax
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import engine
from repro.kernels.common import block_shape_of, index_map_of

from . import Finding
from .capture import trace_spec_calls

_SENTINEL = 0.37  # finite, nonzero, far from any legit zero-carry value


# ---------------------------------------------------------------------------
# Index-map enumeration
# ---------------------------------------------------------------------------

def _block_range(array_shape: tuple, block_shape: tuple) -> tuple:
    return tuple(a // b for a, b in zip(array_shape, block_shape))


def _check_write_coverage(spec, rec, out: list) -> None:
    pts = rec.grid_points()
    for idx, (ospec, oshape) in enumerate(zip(rec.out_specs,
                                              rec.out_shapes)):
        sub = f"{spec.name}.out[{idx}]"
        rng = _block_range(tuple(oshape.shape), block_shape_of(ospec))
        index_map = index_map_of(ospec)
        seen: dict = {}
        for pt in pts:
            blk = tuple(index_map(*pt))
            if any(not (0 <= b < r) for b, r in zip(blk, rng)):
                out.append(Finding("gridcheck", sub,
                                   f"grid point {pt} writes block {blk} "
                                   f"outside the block range {rng} "
                                   f"(Pallas clamps — silent corruption)"))
            elif blk in seen:
                out.append(Finding("gridcheck", sub,
                                   f"grid points {seen[blk]} and {pt} both "
                                   f"write block {blk} — write coverage is "
                                   f"not a bijection"))
            else:
                seen[blk] = pt
        missing = {b for b in np.ndindex(*rng)} - set(seen)
        if missing and not any(f.subject == sub for f in out):
            out.append(Finding("gridcheck", sub,
                               f"blocks never written: {sorted(missing)}"))


def _check_fused_write_coverage(spec, rec, out: list) -> None:
    """Write coverage for the fused ascend/descend grid: the descend-phase
    points (``k >= num_n``) must hit every output block exactly once, and
    every ascend-phase point must PARK the output on the block the first
    descend step overwrites — Pallas writes the bound block back on every
    grid step, so parking anywhere else would clobber finished rows."""
    num_n = rec.grid[-1] // 2
    pts = rec.grid_points()
    for idx, (ospec, oshape) in enumerate(zip(rec.out_specs,
                                              rec.out_shapes)):
        sub = f"{spec.name}.out[{idx}]"
        rng = _block_range(tuple(oshape.shape), block_shape_of(ospec))
        index_map = index_map_of(ospec)
        seen: dict = {}
        for pt in pts:
            blk = tuple(index_map(*pt))
            if any(not (0 <= b < r) for b, r in zip(blk, rng)):
                out.append(Finding("gridcheck", sub,
                                   f"grid point {pt} writes block {blk} "
                                   f"outside the block range {rng} "
                                   f"(Pallas clamps — silent corruption)"))
                continue
            if pt[-1] < num_n:
                first = tuple(index_map(*pt[:-1], num_n))
                if blk != first:
                    out.append(Finding(
                        "gridcheck", sub,
                        f"ascend-phase grid point {pt} parks the output on "
                        f"block {blk}, not on the first descend step's "
                        f"block {first} — the write-back would clobber "
                        f"rows the descend phase has already finished"))
                continue
            if blk in seen:
                out.append(Finding("gridcheck", sub,
                                   f"descend-phase grid points {seen[blk]} "
                                   f"and {pt} both write block {blk} — "
                                   f"write coverage is not a bijection"))
            else:
                seen[blk] = pt
        missing = {b for b in np.ndindex(*rng)} - set(seen)
        if missing and not any(f.subject == sub for f in out):
            out.append(Finding("gridcheck", sub,
                               f"blocks never written by the descend "
                               f"phase: {sorted(missing)}"))


def _chunk_walks(rec, arg_shapes, specs) -> list:
    """(spec_idx, walk) for each N-chunked spec: the sequence of N-chunk
    indices visited as the fast grid axis k advances at fixed j=0."""
    walks = []
    num_n = rec.grid[-1]
    for idx, (spec_, shape) in enumerate(zip(specs, arg_shapes)):
        index_map = index_map_of(spec_)
        bshape = block_shape_of(spec_)
        if bshape == (1, 1):
            continue
        walk = [index_map(0, k) for k in range(num_n)]
        # which tuple position varies with k = the N-chunk coordinate
        varying = [d for d in range(len(walk[0]))
                   if len({w[d] for w in walk}) > 1]
        if not varying:
            continue  # constant over k (a resident block) — not a walk
        walks.append((idx, [w[varying[0]] for w in walk]))
    return walks


def _check_read_bounds(spec, rec, out: list) -> None:
    pts = rec.grid_points()
    for idx, (ispec, shape) in enumerate(zip(rec.in_specs, rec.arg_shapes)):
        sub = f"{spec.name}.in[{idx}]"
        rng = _block_range(tuple(shape), block_shape_of(ispec))
        index_map = index_map_of(ispec)
        bad = sorted({tuple(index_map(*pt)) for pt in pts
                      if any(not (0 <= b < r)
                             for b, r in zip(index_map(*pt), rng))})
        if bad:
            out.append(Finding("gridcheck", sub,
                               f"blocks read outside the block range "
                               f"{rng}: {bad} (Pallas clamps — the kernel "
                               f"would silently re-read an edge chunk)"))


def _check_walk(spec, rec, direction: str, want: list, out: list) -> None:
    """Every N-chunked operand of one kernel walks chunks in ``want``."""
    specs = tuple(rec.in_specs) + tuple(rec.out_specs)
    shapes = tuple(rec.arg_shapes) + tuple(
        tuple(o.shape) for o in rec.out_shapes)
    walks = _chunk_walks(rec, shapes, specs)
    if not walks:
        out.append(Finding("gridcheck", spec.name,
                           f"{direction} kernel has no N-chunked "
                           f"operand at all"))
        return
    for idx, walk in walks:
        if walk != want:
            out.append(Finding(
                "gridcheck", f"{spec.name}.{direction}",
                f"operand {idx} walks N-chunks {walk}, expected "
                f"{want} — the backward maps must exactly mirror the "
                f"forward chunk walk" if direction == "backward" else
                f"operand {idx} walks N-chunks {walk}, expected the "
                f"{direction} walk {want}"))


def _check_mirror(spec, records, out: list) -> None:
    """Forward kernel walks chunks ascending; backward exactly reversed."""
    num_n = records[0].grid[-1]
    ascending = list(range(num_n))
    _check_walk(spec, records[0], "forward", ascending, out)
    _check_walk(spec, records[1], "backward", ascending[::-1], out)


def _check_fused_walks(spec, rec, out: list) -> None:
    """One kernel, two phases on a ``2 * num_n`` chunk axis: the chunk
    inputs ascend ``0..num_n-1`` then park; the output parks then descends
    ``num_n-1..0`` (the mirrored maps); the shared LHS walks the mirror
    ``0..num_n-1..0``.  A descend map that forgets the mirror shows up
    here as the wrong walk."""
    from .capture import TRACE_BLOCK_M
    num_n = rec.grid[-1] // 2
    ks = range(2 * num_n)
    asc_park = [min(k, num_n - 1) for k in ks]
    park_desc = [min(2 * num_n - 1 - k, num_n - 1) for k in ks]
    mirror = [min(k, 2 * num_n - 1 - k) for k in ks]
    specs = tuple(rec.in_specs) + tuple(rec.out_specs)
    n_in = len(rec.in_specs)
    for idx, spec_ in enumerate(specs):
        bshape = block_shape_of(spec_)
        if bshape == (1, 1):
            continue
        sub = f"{spec.name}.fused[{'out' if idx >= n_in else 'in'}]"
        index_map = index_map_of(spec_)
        walk = [index_map(0, k) for k in ks]
        varying = [d for d in range(len(walk[0]))
                   if len({w[d] for w in walk}) > 1]
        if not varying:
            out.append(Finding("gridcheck", sub,
                               f"operand {idx} never varies with the "
                               f"N-chunk axis — a fused kernel streams "
                               f"every non-scalar operand"))
            continue
        got = [w[varying[0]] for w in walk]
        if idx >= n_in:
            want, label = park_desc, "park-then-descend (mirrored output)"
        elif bshape[-1] == TRACE_BLOCK_M:
            want, label = asc_park, "ascend-then-park (chunk operand)"
        else:
            want, label = mirror, "the shared-LHS mirror 0..num_n-1..0"
        if got != want:
            out.append(Finding(
                "gridcheck", sub,
                f"operand {idx} walks N-chunks {got}, expected "
                f"{label}: {want}"))


def _check_recurrence_walk(spec, rec, out: list) -> None:
    """The single recurrence kernel walks chunks ascending, or exactly
    reversed for the reverse variants — all operands agreeing."""
    num_n = rec.grid[-1]
    ascending = list(range(num_n))
    want = ascending[::-1] if spec.reverse else ascending
    direction = "descending" if spec.reverse else "ascending"
    _check_walk(spec, rec, direction, want, out)


# ---------------------------------------------------------------------------
# Mock-executing the kernel bodies (carry protocol)
# ---------------------------------------------------------------------------

class _MockRef:
    """A numpy-backed stand-in for a Pallas ref, good enough for the
    engine's access idioms: ``ref[pl.ds(i, 1), :]``, ``ref[r:r+1,
    pl.ds(i, 1)]``, ``ref[...] = x``, ``jnp.zeros_like(ref)``."""

    def __init__(self, arr):
        self.arr = np.array(arr, dtype=np.float32)

    @property
    def shape(self):
        return self.arr.shape

    @property
    def dtype(self):
        return self.arr.dtype

    def __jax_array__(self):
        return jnp.asarray(self.arr)

    @staticmethod
    def _one(ix):
        if hasattr(ix, "start") and hasattr(ix, "size") and \
                not isinstance(ix, slice):          # pl.ds -> Slice
            start = int(ix.start)
            return slice(start, start + int(ix.size))
        return ix

    def _key(self, key):
        if key is Ellipsis:
            return key
        if isinstance(key, tuple):
            return tuple(self._one(k) for k in key)
        return self._one(key)

    def __getitem__(self, key):
        return jnp.asarray(self.arr[self._key(key)])

    def __setitem__(self, key, val):
        self.arr[self._key(key)] = np.asarray(val)


@contextlib.contextmanager
def _host_kernel_env(program_ids: list):
    """Run kernel bodies eagerly on the host: fori_loop becomes a Python
    loop (so ref indices stay concrete ints), ``pl.when`` executes on the
    concrete predicate, ``pl.program_id`` reads ``program_ids``."""
    real_fori = jax.lax.fori_loop
    real_when = pl.when
    real_pid = pl.program_id

    def fori(lo, hi, body, init, **_kw):
        carry = init
        for t in range(int(lo), int(hi)):
            carry = body(t, carry)
        return carry

    def when(cond):
        def deco(fn):
            if bool(cond):
                fn()
            return fn
        return deco

    jax.lax.fori_loop = fori
    pl.when = when
    pl.program_id = lambda axis: program_ids[axis]
    try:
        yield
    finally:
        jax.lax.fori_loop = real_fori
        pl.when = real_when
        pl.program_id = real_pid


def _operand_data(spec, rec, rng) -> list:
    """Finite, well-conditioned block data per input operand.  For batch
    layouts the main diagonal must dominate — the fused factorisation
    divides by it in-kernel.  (Recurrence gates at 0.2–0.9 are stable
    contractions; nothing divides.)"""
    data = []
    main = {3: 1, 5: 2}[spec.bandwidth] if spec.layout == "batch" else None
    for idx, ispec in enumerate(rec.in_specs):
        shape = block_shape_of(ispec)
        block = rng.uniform(0.2, 0.9, size=shape)
        if main is not None and idx == main and idx < spec.bandwidth:
            block = rng.uniform(2.5, 3.5, size=shape)
        data.append(block.astype(np.float32))
    return data


def _run_probe(rec, in_data, carry_fill, pid) -> list:
    """Execute the kernel body once; returns everything the grid step can
    write besides the carry: the outputs plus any non-carry (fused sweep)
    scratch.  The carry is the LAST scratch operand by builder convention
    and gets ``carry_fill``; other scratch (the fused kernels' full-N
    intermediates) is seeded with a fixed nonzero value so the descend
    phase has live coefficients to thread the carry through."""
    ins = [_MockRef(d) for d in in_data]
    outs = [_MockRef(np.zeros(block_shape_of(s), np.float32))
            for s in rec.out_specs]
    n_scr = len(rec.scratch_shapes)
    scratch = [_MockRef(np.full(tuple(s.shape),
                                carry_fill if i == n_scr - 1 else _SENTINEL,
                                np.float32))
               for i, s in enumerate(rec.scratch_shapes)]
    with _host_kernel_env(list(pid)):
        rec.kernel(*ins, *outs, *scratch)
    return [o.arr for o in outs] + [s.arr for s in scratch[:-1]]


def _check_carry_protocol(spec, records, out: list) -> None:
    fused = getattr(spec, "fused", False)
    labels = (("fused",) if fused
              else ("recurrence",) if len(records) == 1
              else ("forward", "backward"))
    for which, rec in zip(labels, records):
        if not rec.scratch_shapes:
            out.append(Finding("gridcheck", f"{spec.name}.{which}",
                               "streamed kernel has no carry scratch — "
                               "the sweep state cannot thread N-chunks"))
            continue
        rng = np.random.default_rng(3)
        in_data = _operand_data(spec, rec, rng)
        sub = f"{spec.name}.{which}"
        # probe both phase starts for fused kernels: the carry resets at
        # k == 0 (fresh lane tile) AND at k == num_n (descend handover)
        num_n = rec.grid[-1] // 2 if fused else None
        phases = [("k == 0", (1, 0), (0, 1))]
        if fused:
            phases.append((f"k == num_n ({num_n})",
                           (0, num_n), (0, num_n + 1)))
        for phase, reset_pid, thread_pid in phases:
            # phase start: stale carry state must be DEAD
            base = _run_probe(rec, in_data, 0.0, reset_pid)
            stale = _run_probe(rec, in_data, _SENTINEL, reset_pid)
            if any(not np.array_equal(b, s) for b, s in zip(base, stale)):
                out.append(Finding(
                    "gridcheck", sub,
                    f"stale carry scratch leaks into the {phase} chunk — "
                    f"reset_carry missing/broken: the next sweep phase "
                    f"would start from the previous one's final carry "
                    f"state (carry race)"))
            # mid-phase: the carry must actually participate
            base = _run_probe(rec, in_data, 0.0, thread_pid)
            threaded = _run_probe(rec, in_data, _SENTINEL, thread_pid)
            if all(np.array_equal(b, t) for b, t in zip(base, threaded)):
                out.append(Finding(
                    "gridcheck", sub,
                    f"carry scratch is ignored just after {phase} — the "
                    f"sweep state does not thread across N-chunks (the "
                    f"kernel resets unconditionally or never reads its "
                    f"carry)"))


def run() -> list:
    """All gridcheck invariants over every registered streamed spec (the
    resident kernels have a trivial 1-D grid, checked for coverage too)."""
    out: list = []
    for name in sorted(engine.REGISTRY):
        spec = engine.REGISTRY[name]
        fused = getattr(spec, "fused", False)
        records = trace_spec_calls(spec)
        for rec in records:
            if fused:
                _check_fused_write_coverage(spec, rec, out)
            else:
                _check_write_coverage(spec, rec, out)
            _check_read_bounds(spec, rec, out)
        if not spec.streamed:
            continue
        if len(records) != spec.num_pallas_calls:
            out.append(Finding("gridcheck", spec.name,
                               f"streamed spec emitted {len(records)} "
                               f"pallas_call(s), expected "
                               f"{spec.num_pallas_calls}"))
            continue
        if isinstance(spec, engine.RecurrenceSpec):
            _check_recurrence_walk(spec, records[0], out)
        elif fused:
            _check_fused_walks(spec, records[0], out)
        else:
            _check_mirror(spec, records, out)
        _check_carry_protocol(spec, records, out)
    return out
