"""``repro.analysis`` — static verification (speclint) for the sweep engine.

The declarative engine (``repro.kernels.engine``) made every banded Pallas
solver a table lookup: a ``SweepSpec`` plus two ``PassSpec`` rows *are* the
kernel.  That is the paper's premise made maintainable — and it means a
one-character table edit can silently break bit-exactness, the roofline
traffic model, or the streamed grid's carry sequencing.  PRs 3–4 each
burned a debug cycle on exactly these defect classes (traced-eps
concretization, dead-lane 1/0 NaNs, a hardcoded itemsize in the traffic
accounting).  This package proves the invariants statically, before any
solve runs:

  * ``speccheck`` — structural invariants over the pass tables (carry lags
    bounded by the order, coefficient rows inside the stacked LHS, exactly
    one inverse-diagonal scale per pass pair, transposed twins = same
    machine with the scale moved) PLUS an independent recount of the HBM
    traffic and VMEM residency by abstract interpretation of the kernel
    builders — cross-checked against ``SweepSpec.traffic_words`` /
    ``vmem_counts`` so the roofline model can never drift from the code.
    The fused single-call variants are swept too (one ``pallas_call``,
    strictly fewer words than their two-call siblings, full-N scratch
    recounted) along with the bf16 per-operand storage pricing.
  * ``gridcheck`` — enumerates every streamed ``BlockSpec`` index map over
    the 2-D split-N grid: write coverage must be a bijection, reads must
    stay in bounds, the backward chunk walk must exactly mirror the
    forward one (for the fused kernels: ascend-then-park chunk walks, a
    park-then-descend output, and the shared-LHS mirror on ONE grid), and
    the carry scratch must be insensitive to stale state at ``k == 0``
    (a dropped ``reset_carry`` is a cross-lane-tile carry race; fused
    kernels are probed again at the ``k == num_n`` descend handover).
  * ``tracecheck`` — the jit contract: every registered backend x mode
    solves under ``jax.eval_shape`` with fully traced ``Factorization``
    leaves (poisoning any concretization), ``SolveMeta`` stays hashable,
    and an AST lint flags ``float(`` / ``int(`` / ``.item()`` /
    ``np.asarray`` on potentially-traced values in ``repro.kernels`` /
    ``repro.solver`` (``# speclint: allow-concretize`` marks legitimate
    host-side sites).
  * ``mutation`` — a self-test that seeds known defects (swapped
    subtraction order, off-by-one index map, dropped ``reset_carry``,
    baked ``float(eps)``, stale traffic/VMEM constants, a fused descend
    map that forgets the mirror) and asserts each checker catches its
    class, so the linter cannot rot into a no-op.
  * ``nansweep`` — a registry-driven sanitizer sweep: padded / ragged /
    dead-lane cases auto-generated for every ``REGISTRY`` spec and every
    pure backend, run under debug-NaNs (CI's nan-guard job; a new spec can
    no longer ship un-guarded).

CLI: ``python -m repro.analysis`` (add ``--self-test`` / ``--nan-sweep``).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verification failure: which checker, on what, and why."""

    checker: str   # "speccheck" | "gridcheck" | "tracecheck" | "astlint" | ...
    subject: str   # spec name, backend/mode combo, or file:line
    message: str

    def __str__(self) -> str:
        return f"[{self.checker}] {self.subject}: {self.message}"


def run_all(verbose: bool = False) -> list:
    """Run every checker over the full current registry; returns findings
    (empty = the whole support matrix is speclint-clean)."""
    from . import gridcheck, speccheck, tracecheck

    findings = []
    for name, runner in (("speccheck", speccheck.run),
                         ("gridcheck", gridcheck.run),
                         ("tracecheck", tracecheck.run)):
        got = runner()
        if verbose:
            print(f"{name}: {len(got)} finding(s)")
        findings.extend(got)
    return findings


__all__ = ["Finding", "run_all"]
