"""speccheck — structural invariants of the pass tables + accounting audit.

Every check here is over the *declarative* layer: the ``SweepSpec`` /
``PassSpec`` tables that generate the kernels, and the accounting those
specs derive.  Nothing solves anything.

Structural invariants (the bit-exactness contract of DESIGN.md §2.2):

  * every carry lag lies in ``[1, order]`` and each pass touches the full
    lag range (an order-2 sweep that never reads lag 2 is a different —
    wrong — recurrence);
  * every integer coefficient row index addresses a real row of the
    stacked LHS (``< lhs_rows``; batch back-substitution rows ``<
    n_coefs``); the EPS sentinel appears exactly once, and only in
    uniform specs;
  * exactly ONE inverse-diagonal scale across each pass pair, on the
    stored-inverse row (``scale_row``) — forward variants scale the
    forward pass, transposed variants the backward pass (A = L·U vs
    A^T = U^T·L^T);
  * subtraction order is canonical: forward-pass lags strictly
    descending, backward-pass lags strictly ascending (float subtraction
    is not associative — this IS the instruction order of the
    pre-engine kernels the generated bodies are bit-exact against);
  * the transposed twin is the same machine with the scale moved: same
    term tables (same lag sequences for uniform, where eps migrates from
    the forward to the backward pass), scale on the other side;
  * streamed and resident siblings share one pass table (streaming moves
    carries to scratch, never the arithmetic).

Accounting audit: the HBM-traffic and VMEM numbers ``SweepSpec`` derives
are recounted INDEPENDENTLY from the captured kernel builders
(``repro.analysis.capture``) and must agree exactly — a stale constant in
``traffic_words`` / ``vmem_counts`` (or a builder change that silently
adds a stream) fails here, in isolation.
"""

from __future__ import annotations

from repro.kernels import engine
from repro.kernels.common import shard_lanes
from repro.kernels.engine import EPS_PARAM, RecurrenceSpec, SweepSpec

from . import Finding
from .capture import (TRACE_M, TRACE_N, recount_traffic_words,
                      recount_vmem_counts, trace_spec_calls)


def _lags(pspec) -> tuple:
    return tuple(lag for _src, lag in pspec.terms)


def _check_terms(spec: SweepSpec, pspec, which: str, out: list) -> None:
    """Lag bounds, row bounds, EPS placement, subtraction order."""
    sub = f"{spec.name}.{which}"
    max_row = spec.lhs_rows if spec.layout == "shared" else spec.n_coefs
    for src, lag in pspec.terms:
        if not (1 <= lag <= spec.order):
            out.append(Finding("speccheck", sub,
                               f"carry lag {lag} outside [1, {spec.order}] "
                               f"(order-{spec.order} recurrence)"))
        if src == EPS_PARAM:
            if not spec.uniform:
                out.append(Finding("speccheck", sub,
                                   "EPS parameter term in a non-uniform "
                                   "spec (eps rides a (1, 1) operand only "
                                   "for cuPentUniformBatch variants)"))
        elif not (isinstance(src, int) and 0 <= src < max_row):
            out.append(Finding("speccheck", sub,
                               f"coefficient row {src!r} outside the "
                               f"stacked LHS (valid rows: 0..{max_row - 1})"))
    lags = _lags(pspec)
    if sorted(lags) != list(range(1, spec.order + 1)):
        out.append(Finding("speccheck", sub,
                           f"pass lags {lags} do not cover the carry range "
                           f"1..{spec.order} exactly once"))
    want = tuple(sorted(lags, reverse=(which == "fwd")))
    if lags != want:
        out.append(Finding("speccheck", sub,
                           f"subtraction order {lags} violates the "
                           f"canonical order {want} (fwd descending / bwd "
                           f"ascending — the bit-exactness contract)"))
    if pspec.scale is not None and pspec.scale != spec.scale_row:
        out.append(Finding("speccheck", sub,
                           f"scale row {pspec.scale!r} is not the stored "
                           f"inverse-diagonal row {spec.scale_row}"))


def _check_structure(spec: SweepSpec, out: list) -> None:
    fwd, bwd = spec.passes()
    if spec.layout == "batch":
        if fwd is not None:
            out.append(Finding("speccheck", spec.name,
                               "batch layout has a forward PassSpec (the "
                               "fused factorisation owns the forward pass)"))
        if bwd.scale is not None:
            out.append(Finding("speccheck", spec.name,
                               "batch back-substitution is scaled (the "
                               "fused factorisation already divided)"))
        _check_terms(spec, bwd, "bwd", out)
        return

    _check_terms(spec, fwd, "fwd", out)
    _check_terms(spec, bwd, "bwd", out)

    # exactly one inverse-diagonal scale, on the transposed-dependent side
    scaled = [name for name, p in (("fwd", fwd), ("bwd", bwd))
              if p.scale is not None]
    want_side = "bwd" if spec.transposed else "fwd"
    if scaled != [want_side]:
        out.append(Finding(
            "speccheck", spec.name,
            f"inverse-diagonal scale on {scaled or ['neither pass']}, "
            f"expected exactly one on the {want_side} pass "
            f"({'A^T = U^T*L^T scales back-substitution' if spec.transposed else 'A = L*U scales forward substitution'})"))

    # EPS placement: uniform specs read eps in the unscaled outer-band term
    eps_in = [name for name, p in (("fwd", fwd), ("bwd", bwd))
              for src, _lag in p.terms if src == EPS_PARAM]
    if spec.uniform:
        want_eps = ["bwd" if spec.transposed else "fwd"]
        if eps_in != want_eps:
            out.append(Finding("speccheck", spec.name,
                               f"EPS parameter read in {eps_in or 'no'} "
                               f"pass(es), expected exactly once in the "
                               f"{want_eps[0]} pass"))
    elif eps_in:
        out.append(Finding("speccheck", spec.name,
                           "non-uniform spec reads the EPS parameter"))


def _check_recurrence_structure(spec: RecurrenceSpec, out: list) -> None:
    """The gate-operand contract: a recurrence is ONE unscaled pass whose
    multiplicative coefficients are per-token gate operands, wired so the
    lag-k carry reads gate operand k-1 (the operand order the dispatcher
    ``ops.recurrence`` passes) with lags ascending — the subtraction
    order of the generated body, part of the resident==streamed
    bit-exactness contract."""
    passes = spec.passes()
    if len(passes) != 1:
        out.append(Finding("speccheck", spec.name,
                           f"recurrence spec runs {len(passes)} passes — a "
                           f"gated recurrence has no back-substitution "
                           f"partner, it must be a single pass"))
        return
    (pspec,) = passes
    sub = f"{spec.name}.pass"
    if pspec.scale is not None:
        out.append(Finding("speccheck", sub,
                           f"recurrence pass is scaled by {pspec.scale!r} — "
                           f"gated recurrences have no stored inverse "
                           f"diagonal"))
    lags = _lags(pspec)
    if lags != tuple(range(1, spec.order + 1)):
        out.append(Finding("speccheck", sub,
                           f"pass lags {lags} are not the ascending carry "
                           f"range 1..{spec.order} (gate-operand order is "
                           f"part of the bit-exactness contract)"))
    for src, lag in pspec.terms:
        if src == EPS_PARAM:
            out.append(Finding("speccheck", sub,
                               "recurrence pass reads the EPS parameter "
                               "(a uniform-penta concept)"))
        elif src != lag - 1:
            out.append(Finding("speccheck", sub,
                               f"lag-{lag} carry reads gate operand {src!r}, "
                               f"expected operand {lag - 1} — the gate "
                               f"operands are wired to the wrong lags"))


def _check_recurrence_twin(spec: RecurrenceSpec, out: list) -> None:
    """The reversed twin is the same machine walked the other way: same
    pass table, only the walk direction differs."""
    if spec.reverse:
        return
    twin = engine.REGISTRY.get(spec.twin_name())
    if twin is None:
        out.append(Finding("speccheck", spec.name,
                           f"reversed twin {spec.twin_name()!r} is not "
                           f"registered"))
        return
    if spec.passes() != twin.passes():
        out.append(Finding("speccheck", spec.name,
                           f"reversed twin {twin.name} runs a different "
                           f"pass table — reversal only mirrors the walk, "
                           f"it never re-wires the gate terms"))


def _check_twin(spec: SweepSpec, out: list) -> None:
    """Transposed twin = the same machine with the scale moved."""
    if spec.layout == "batch" or spec.transposed:
        return
    twin_name = spec.twin_name()
    twin = engine.REGISTRY.get(twin_name)
    if twin is None:
        out.append(Finding("speccheck", spec.name,
                           f"transposed twin {twin_name!r} is not "
                           f"registered"))
        return
    fwd, bwd = spec.passes()
    tfwd, tbwd = twin.passes()
    if (_lags(fwd), _lags(bwd)) != (_lags(tfwd), _lags(tbwd)):
        out.append(Finding("speccheck", spec.name,
                           f"twin {twin_name} runs different lag sequences "
                           f"({(_lags(tfwd), _lags(tbwd))} vs "
                           f"{(_lags(fwd), _lags(bwd))}) — not the same "
                           f"sweep machine"))
    if not spec.uniform and (fwd.terms, bwd.terms) != (tfwd.terms,
                                                       tbwd.terms):
        out.append(Finding("speccheck", spec.name,
                           f"twin {twin_name} reads different coefficient "
                           f"terms — transposition only shifts rows on the "
                           f"host and moves the scale, it never re-wires "
                           f"the term table"))
    if (fwd.scale, tbwd.scale) != (spec.scale_row, twin.scale_row) or \
            (bwd.scale, tfwd.scale) != (None, None):
        out.append(Finding("speccheck", spec.name,
                           f"scale not moved fwd->bwd between {spec.name} "
                           f"and {twin_name}"))


def _check_streamed_sibling(spec, out: list) -> None:
    if not spec.streamed:
        return
    resident = engine.REGISTRY.get(spec.resident_name)
    if resident is None:
        out.append(Finding("speccheck", spec.name,
                           f"resident sibling {spec.resident_name!r} is "
                           f"not registered"))
        return
    if spec.passes() != resident.passes():
        out.append(Finding("speccheck", spec.name,
                           "streamed variant runs a different pass table "
                           "than its resident sibling (streaming must "
                           "move carries, never arithmetic)"))


def _check_fused_sibling(spec, out: list) -> None:
    """A fused spec is its two-call sibling collapsed into one call: same
    pass table, ONE pallas_call, and strictly fewer modelled HBM words —
    the whole point of fusing is deleting the intermediate round trip."""
    if not getattr(spec, "fused", False):
        return
    sibling = engine.REGISTRY.get(spec.unfused_name)
    if sibling is None:
        out.append(Finding("speccheck", spec.name,
                           f"two-call sibling {spec.unfused_name!r} is not "
                           f"registered (fused specs must keep their spill "
                           f"fallback)"))
        return
    if spec.passes() != sibling.passes():
        out.append(Finding("speccheck", spec.name,
                           "fused variant runs a different pass table than "
                           "its two-call sibling (fusing must move the "
                           "intermediate to scratch, never arithmetic)"))
    if spec.num_pallas_calls != 1:
        out.append(Finding("speccheck", spec.name,
                           f"fused spec claims {spec.num_pallas_calls} "
                           f"pallas_calls — fusing means ONE"))
    got = spec.traffic_words(TRACE_N, TRACE_M)
    sib = sibling.traffic_words(TRACE_N, TRACE_M)
    if got >= sib:
        out.append(Finding("speccheck", spec.name,
                           f"fused traffic ({got} words) is not below the "
                           f"two-call sibling's ({sib}) — the fusion saves "
                           f"nothing"))


def _check_accounting(spec, out: list) -> None:
    """Recount traffic + VMEM from the captured builders; exact match."""
    records = trace_spec_calls(spec)
    want_calls = spec.num_pallas_calls
    if len(records) != want_calls:
        out.append(Finding("speccheck", spec.name,
                           f"builder emitted {len(records)} pallas_call(s), "
                           f"expected {want_calls}"))
        return
    got = recount_traffic_words(records)
    want = spec.traffic_words(TRACE_N, TRACE_M)
    if got != want:
        out.append(Finding(
            "speccheck", spec.name,
            f"HBM traffic drift: builders move {got} words at "
            f"(N={TRACE_N}, M={TRACE_M}) but SweepSpec.traffic_words "
            f"claims {want} — the roofline model no longer matches the "
            f"code"))
    got_vmem = recount_vmem_counts(records)
    want_vmem = tuple(spec.vmem_counts()) + (spec.sweep_scratch(),)
    # resident kernels carry sweep state in registers, not scratch — only
    # the first two classes are observable (and used by check_vmem);
    # streamed pairs add the carry rows, fused kernels the full-N scratch
    fused = getattr(spec, "fused", False)
    compare = 4 if fused else (3 if spec.streamed else 2)
    labels = ("blocks", "lhs_vecs", "carry_rows", "sweep_scratch")
    if got_vmem[:compare] != want_vmem[:compare]:
        out.append(Finding(
            "speccheck", spec.name,
            f"VMEM residency drift: builders hold {got_vmem[:compare]} "
            f"({', '.join(labels[:compare])}) "
            f"but SweepSpec.vmem_counts claims "
            f"{want_vmem[:compare]} — the budget check no longer "
            f"matches the code"))


def _check_storage_pricing(spec, out: list) -> None:
    """Mixed-precision pricing sweep: ``traffic_bytes`` must price the
    STORED operand words at the storage itemsize and the writes /
    intermediates at the fp32-promoted compute itemsize — the per-operand
    itemsize split the bf16 storage path's halved-bytes claim rests on."""
    import jax.numpy as jnp
    n, m = TRACE_N, TRACE_M
    f32 = spec.traffic_bytes(n, m, jnp.float32)
    bf16 = spec.traffic_bytes(n, m, jnp.float32, jnp.dtype(jnp.bfloat16))
    want = 2 * spec.storage_words(n, m) + 4 * spec.compute_words(n, m)
    if bf16 != want:
        out.append(Finding(
            "speccheck", spec.name,
            f"bf16-storage pricing drift: traffic_bytes says {bf16} but "
            f"storage_words x 2 + compute_words x 4 = {want} — the "
            f"per-operand itemsize split no longer holds"))
    if not bf16 < f32:
        out.append(Finding(
            "speccheck", spec.name,
            f"bf16 storage does not reduce modelled bytes ({bf16} vs "
            f"{f32} at fp32) — the spec stores nothing at the storage "
            f"dtype?"))


def _check_sharded_traffic(spec, out: list) -> None:
    """The per-device model is the single-device model at the local lane
    count — guard the two code paths against diverging."""
    for n_shards in (1, 3):
        got = spec.sharded_traffic_words(TRACE_N, TRACE_M, n_shards)
        want = spec.traffic_words(TRACE_N, shard_lanes(TRACE_M, n_shards))
        if got != want:
            out.append(Finding(
                "speccheck", spec.name,
                f"sharded traffic at {n_shards} shard(s) is {got} words, "
                f"expected the single-device model at the local lane "
                f"count ({want})"))


def run() -> list:
    """All speccheck invariants over every registered spec."""
    out: list = []
    for name in sorted(engine.REGISTRY):
        spec = engine.REGISTRY[name]
        if spec.name != name:
            out.append(Finding("speccheck", name,
                               f"registry key disagrees with spec.name "
                               f"({spec.name!r})"))
        if isinstance(spec, RecurrenceSpec):
            _check_recurrence_structure(spec, out)
            _check_recurrence_twin(spec, out)
        else:
            _check_structure(spec, out)
            _check_twin(spec, out)
        _check_streamed_sibling(spec, out)
        _check_fused_sibling(spec, out)
        _check_accounting(spec, out)
        _check_storage_pricing(spec, out)
        _check_sharded_traffic(spec, out)
    return out
