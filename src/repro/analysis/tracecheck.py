"""tracecheck — the jit contract of every registered backend x mode.

A ``Factorization`` crosses ``jit`` / ``vmap`` / ``grad`` / ``lax.scan``
only if (a) its meta stays hashable and (b) NO code under ``solve`` /
``transpose_solve`` concretizes a traced leaf.  Both properties are
invisible to the test suite until someone actually jits the failing
combination (PR 3's ``float(f.eps[2])`` broke exactly this way).

This checker proves the contract without running a single solve: every
registered pure backend x storage mode x boundary condition is driven
through ``jax.eval_shape`` with the factorization's leaves replaced by
``ShapeDtypeStruct``s — FULLY traced values with no data at all, so any
``float()`` / ``.item()`` / host round-trip on a leaf raises immediately
(abstract values poison concretization by construction).  ``SolveMeta``
hashability is asserted on the way.  The backend list comes from the
registry (``available_pure_backends``), so a newly registered backend is
contract-checked automatically.

Combinations a backend *declares* unsupported (``NotImplementedError``
from ``factorize`` — e.g. pallas on periodic x batch) are recorded as
skips, not findings: the contract is about what a backend claims to
serve.

The second half is the AST lint (``repro.analysis.lint``): the same
defect class caught at the source level across ``repro.kernels`` /
``repro.solver``, including paths no current meta combination reaches.
"""

from __future__ import annotations

import numpy as np

import jax

from . import Finding
from . import lint as _lint

#: (n, m) of the contract-check systems — tiny; nothing ever solves.
CHECK_N, CHECK_M = 32, 16


def _case_system(bandwidth: int, mode: str, periodic: bool):
    """A well-conditioned BandedSystem for one matrix-cell case."""
    from repro.solver import BandedSystem

    rng = np.random.default_rng(bandwidth)
    n = CHECK_N
    if mode == "uniform":
        if bandwidth == 3:
            diags = (-1.0, 4.0, -1.0)
        else:
            s = 0.11
            diags = (s, -4 * s, 1 + 6 * s, -4 * s, s)
        diags = tuple(np.full(n, v, np.float32) for v in diags)
    else:
        off = [rng.uniform(-1, 1, n).astype(np.float32)
               for _ in range(bandwidth - 1)]
        main = (sum(np.abs(o) for o in off)
                + np.float32(bandwidth - 1.0)).astype(np.float32)
        diags = (*off[:bandwidth // 2], main, *off[bandwidth // 2:])
    ctor = BandedSystem.tridiag if bandwidth == 3 else BandedSystem.penta
    return ctor(*diags, n=n, periodic=periodic, mode=mode,
                batch=CHECK_M if mode == "batch" else None)


def _abstract(tree):
    """Replace every traced leaf by a ShapeDtypeStruct (data-free)."""
    return jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(np.shape(leaf),
                                          np.asarray(leaf).dtype), tree)


def contract_cases() -> list:
    """(backend, bandwidth, mode, periodic) for the full support matrix."""
    from repro.solver.registry import available_pure_backends

    return [(backend, bw, mode, periodic)
            for backend in available_pure_backends()
            for bw in (3, 5)
            for mode in ("constant", "uniform", "batch")
            for periodic in (False, True)]


def check_case(backend: str, bandwidth: int, mode: str,
               periodic: bool) -> list:
    """Findings for one backend x mode x bc cell (empty = contract holds,
    or the backend declared the cell unsupported)."""
    from repro.solver import factorize, solve, transpose_solve

    sub = (f"{backend}/{'tridiag' if bandwidth == 3 else 'penta'}/"
           f"{'periodic' if periodic else 'dirichlet'}/{mode}")
    system = _case_system(bandwidth, mode, periodic)
    try:
        fact = factorize(system, backend=backend)
    except NotImplementedError:
        return []  # declared unsupported — not a contract violation
    except Exception as exc:  # noqa: BLE001 — every failure is a finding
        return [Finding("tracecheck", sub,
                        f"factorize raised {type(exc).__name__}: {exc}")]

    out: list = []
    try:
        hash(fact.meta)
    except TypeError as exc:
        out.append(Finding("tracecheck", sub,
                           f"SolveMeta is unhashable ({exc}) — the "
                           f"factorization cannot cross jit boundaries"))
        return out

    abstract_fact = _abstract(fact)
    rhs = jax.ShapeDtypeStruct((system.n, CHECK_M), np.float32)
    for name, fn in (("solve", solve),
                     ("transpose_solve", transpose_solve)):
        try:
            got = jax.eval_shape(fn, abstract_fact, rhs)
        except Exception as exc:  # noqa: BLE001
            out.append(Finding(
                "tracecheck", sub,
                f"{name} breaks under tracing with fully traced "
                f"Factorization leaves — {type(exc).__name__}: "
                f"{str(exc).splitlines()[0]}"))
            continue
        if tuple(got.shape) != (system.n, CHECK_M):
            out.append(Finding("tracecheck", sub,
                               f"{name} traced to shape {got.shape}, "
                               f"expected {(system.n, CHECK_M)}"))
    return out


def recurrence_cases() -> list:
    """(order, reverse, with_h0) for the gated-recurrence Pallas front
    end — every registered walk direction, seeded and zero-carry."""
    return [(order, reverse, with_h0)
            for order in (1, 2)
            for reverse in (False, True)
            for with_h0 in (False, True)]


def check_recurrence_case(order: int, reverse: bool, with_h0: bool) -> list:
    """The ``method="pallas"`` dispatch of ``core.recurrence`` must trace
    with fully abstract operands (gates, additive operand, h0 seeds) —
    any concretization in the dispatcher's block tuning, h0 folding or
    custom_vjp plumbing raises here, without a solve ever running."""
    from repro.core.recurrence import linear_recurrence, linear_recurrence2

    sub = (f"pallas/recur{order}/"
           f"{'reverse' if reverse else 'forward'}/"
           f"{'seeded' if with_h0 else 'zero-carry'}")
    op = jax.ShapeDtypeStruct((CHECK_N, CHECK_M), np.float32)
    seed = jax.ShapeDtypeStruct((CHECK_M,), np.float32)
    if order == 1:
        def fn(p, q, *h):
            return linear_recurrence(p, q, *h, reverse=reverse,
                                     method="pallas", interpret=True)
        args = (op, op, seed) if with_h0 else (op, op)
    else:
        def fn(s, t, u, *h):
            h0 = (h[0], h[1]) if h else None
            return linear_recurrence2(s, t, u, h0, reverse=reverse,
                                      method="pallas", interpret=True)
        args = (op, op, op, seed, seed) if with_h0 else (op, op, op)
    try:
        got = jax.eval_shape(fn, *args)
    except Exception as exc:  # noqa: BLE001
        return [Finding(
            "tracecheck", sub,
            f"pallas recurrence breaks under tracing with abstract "
            f"operands — {type(exc).__name__}: "
            f"{str(exc).splitlines()[0]}")]
    if tuple(got.shape) != (CHECK_N, CHECK_M):
        return [Finding("tracecheck", sub,
                        f"traced to shape {got.shape}, expected "
                        f"{(CHECK_N, CHECK_M)}")]
    return []


def run() -> list:
    """The full jit-contract matrix + the concretization AST lint."""
    out: list = []
    for case in contract_cases():
        out.extend(check_case(*case))
    for rcase in recurrence_cases():
        out.extend(check_recurrence_case(*rcase))
    out.extend(_lint.run())
    return out
