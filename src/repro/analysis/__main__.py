"""CLI: ``python -m repro.analysis`` — speclint the whole support matrix.

Exit code 0 = clean, 1 = findings (or a missed mutation).  Modes:

  (default)     run speccheck + gridcheck + tracecheck (incl. AST lint)
  --self-test   run the mutation self-test (each seeded defect class must
                be caught by its checker)
  --nan-sweep   run the registry-driven debug-NaNs sweep (CI's nan-guard)
  --all         everything above
"""

from __future__ import annotations

import os
import sys
import argparse

# Harmless on a real accelerator; on CPU hosts this gives the sharded
# backend the multi-device mesh some checks trace against.  Must happen
# before jax initializes its backends.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification (speclint) of the sweep-kernel "
                    "engine: pass-table invariants, streamed-grid index "
                    "maps, the jit contract, and the traffic/VMEM "
                    "accounting — no solver ever runs.")
    parser.add_argument("--self-test", action="store_true",
                        help="mutation self-test: seed known defect classes "
                             "and require the analyzer to catch each")
    parser.add_argument("--nan-sweep", action="store_true",
                        help="registry-driven padded/ragged/dead-lane "
                             "sweep under debug-NaNs")
    parser.add_argument("--all", action="store_true",
                        help="checkers + self-test + nan-sweep")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress per-checker progress lines")
    args = parser.parse_args(argv)

    from repro.kernels.engine import REGISTRY

    from . import run_all
    verbose = not args.quiet
    failed = False
    run_checkers = args.all or not (args.self_test or args.nan_sweep)

    if run_checkers:
        findings = run_all(verbose=verbose)
        for f in findings:
            print(f, file=sys.stderr)
        if findings:
            failed = True
        elif verbose:
            print(f"speclint clean: {len(REGISTRY)} registered specs, "
                  f"0 findings")

    if args.self_test or args.all:
        from . import mutation
        if verbose:
            print("mutation self-test:")
        results = mutation.self_test(verbose=verbose)
        missed = [r.name for r in results if not r.detected]
        if missed:
            print(f"mutation self-test MISSED: {', '.join(missed)}",
                  file=sys.stderr)
            failed = True
        elif verbose:
            print(f"mutation self-test: {len(results)}/{len(results)} "
                  f"defect classes caught")

    if args.nan_sweep or args.all:
        from . import nansweep
        findings = nansweep.run()
        for f in findings:
            print(f, file=sys.stderr)
        if findings:
            failed = True
        elif verbose:
            print(f"nan-sweep clean: {len(REGISTRY)} specs x "
                  f"{len(nansweep.CASES)} shape classes")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
