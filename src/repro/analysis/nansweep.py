"""Registry-driven NaN/sanitizer sweep over every generated kernel.

PR 3's dead-lane NaN bug is the motivating defect class: padding the
batch axis with zeros made the fused factorisation divide by the zero
pad, flooding the (sliced-off) padding with inf/NaN — harmless to the
answer, fatal under ``JAX_DEBUG_NANS`` and to the flush-to-zero path.
The guard against regressions used to be a hand-kept list of test files
in CI; this sweep derives the cases from the engine ``REGISTRY`` instead,
so a newly registered variant is sanitizer-covered the day it lands.

Per registered spec, the ops-layer entry point (``ops.entry_point``) runs
under ``jax_debug_nans`` on three shape classes:

  * **ragged** — both axes off the tile multiples (lane AND sweep
    padding active);
  * **dead-lane** — a tiny batch against a large lane tile (the padding
    dominates: most lanes are dead);
  * **aligned** — exact multiples (the identity-padding code paths must
    also stay silent when they are no-ops).

Any non-finite value in an intermediate raises immediately (debug-nans),
and the sliced outputs are additionally checked finite.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import penta_factor, thomas_factor
from repro.kernels import engine, ops

from . import Finding

#: (case name, n, m, block_m, block_n) — block_n only used when streamed.
CASES = (
    ("ragged", 45, 70, 64, 16),
    ("dead-lane", 33, 3, 64, 16),
    ("aligned", 48, 64, 64, 16),
)


def _shared_factor(spec, rng, n):
    if spec.bandwidth == 3:
        a = rng.uniform(-1, 1, n).astype(np.float32)
        c = rng.uniform(-1, 1, n).astype(np.float32)
        b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
        return thomas_factor(*map(jnp.asarray, (a, b, c)))
    if spec.uniform:
        one = np.ones(n, np.float32)
        s = 0.11
        coeffs = (s * one, -4 * s * one, (1 + 6 * s) * one,
                  -4 * s * one, s * one)
    else:
        a, b, d, e = (rng.uniform(-1, 1, n).astype(np.float32)
                      for _ in range(4))
        c = (np.abs(a) + np.abs(b) + np.abs(d) + np.abs(e) + 4.0).astype(
            np.float32)
        coeffs = (a, b, c, d, e)
    return penta_factor(*map(jnp.asarray, coeffs))


def _batch_diags(spec, rng, n, m):
    k = spec.bandwidth - 1
    off = [rng.uniform(-1, 1, (n, m)).astype(np.float32) for _ in range(k)]
    main = (sum(np.abs(o) for o in off) + np.float32(k + 1.0)).astype(
        np.float32)
    return tuple(map(jnp.asarray,
                     (*off[:k // 2], main, *off[k // 2:])))


def _recurrence_gates(spec, rng, n, m):
    """Stable per-token gates: |s| + |t| < 1 bounds every carry, so the
    sweep (and its zero padding) stays finite under debug-nans."""
    scales = (0.9,) if spec.order == 1 else (0.6, 0.3)
    return tuple(jnp.asarray(rng.uniform(-s, s, (n, m)).astype(np.float32))
                 for s in scales)


def _dispatch(spec, rng, n, m, block_m, block_n):
    """One solve of ``spec`` through its ops entry point; returns (n, m)."""
    fn = ops.entry_point(spec)
    rhs = jnp.asarray(rng.uniform(-1, 1, (n, m)).astype(np.float32))
    bn = block_n if spec.streamed else None
    if spec.layout == "recurrence":
        return fn(*_recurrence_gates(spec, rng, n, m), rhs,
                  reverse=spec.reverse, block_m=block_m, block_n=bn,
                  interpret=True)
    fused = getattr(spec, "fused", False)
    if spec.layout == "batch":
        return fn(*_batch_diags(spec, rng, n, m), rhs, block_m=block_m,
                  block_n=bn, fused=fused, interpret=True)
    f = _shared_factor(spec, rng, n)
    kwargs = dict(block_m=block_m, block_n=bn, fused=fused, interpret=True,
                  transposed=spec.transposed)
    if spec.bandwidth == 5:
        kwargs["uniform"] = spec.uniform
    return fn(f, rhs, **kwargs)


def run() -> list:
    """Every REGISTRY spec x shape class under debug-nans; findings on any
    raised NaN or non-finite output."""
    out: list = []
    debug_nans_was = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", True)
    try:
        for name in sorted(engine.REGISTRY):
            spec = engine.REGISTRY[name]
            for case, n, m, block_m, block_n in CASES:
                sub = f"{spec.name}[{case} n={n} m={m}]"
                rng = np.random.default_rng(7)
                try:
                    x = _dispatch(spec, rng, n, m, block_m, block_n)
                except FloatingPointError as exc:
                    out.append(Finding(
                        "nansweep", sub,
                        f"debug-nans tripped in an intermediate: "
                        f"{str(exc).splitlines()[0]} — padding is being "
                        f"fed through a divide (dead-lane NaN class)"))
                    continue
                except Exception as exc:  # noqa: BLE001
                    out.append(Finding("nansweep", sub,
                                       f"dispatch raised "
                                       f"{type(exc).__name__}: {exc}"))
                    continue
                vals = np.asarray(x)
                if vals.shape != (n, m):
                    out.append(Finding("nansweep", sub,
                                       f"output shape {vals.shape}, "
                                       f"expected {(n, m)}"))
                if not np.isfinite(vals).all():
                    out.append(Finding(
                        "nansweep", sub,
                        f"{int((~np.isfinite(vals)).sum())} non-finite "
                        f"value(s) in the sliced output"))
    finally:
        jax.config.update("jax_debug_nans", debug_nans_was)
    return out
