"""Logical-axis sharding with divisibility-aware first-fit resolution.

Every tensor dimension carries a logical name; rules map names to an ordered
list of CANDIDATE mesh-axis groups. Resolution walks the dims of a tensor in
order and assigns the first candidate whose mesh axes (a) all exist in the
mesh, (b) are not already used by another dim of the same tensor, and
(c) divide the dimension size evenly. Unresolvable dims stay replicated.

This absorbs awkward published configs without special-casing:
  * minitron-4b's 24 heads on a 16-way model axis -> heads stay replicated,
    the d_ff / fused-QKV projections still shard;
  * GQA kv=8 caches on model=16 -> `kv` fails, the next dim in the tensor
    (`kv_seq` or `head_dim`) picks the axis up;
  * MQA kv=1 -> always replicated, exactly what you want;
  * single-pod vs multi-pod -> candidates name ("pod","data") and missing
    axes are simply dropped.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisGroup = tuple[str, ...]


def _as_group(cand) -> AxisGroup:
    if isinstance(cand, str):
        return (cand,)
    return tuple(cand)


# Candidates are ordered: first-fit. Params use FSDP-style data sharding on
# the "embed"-like dims and tensor parallelism on heads/mlp/vocab/experts;
# activations shard batch over data axes and heads/mlp over model.
DEFAULT_RULES: dict[str, list] = {
    # ---- parameter dims ----
    "vocab": ["model"],
    "embed": [("pod", "data")],          # ZeRO-3 / FSDP shard of weights
    "mlp": ["model"],
    "heads": ["model"],
    "kv": ["model"],
    "head_dim": ["model"],
    "experts": ["model"],                # expert parallelism
    "expert_mlp": [],                    # within-expert ff dim (EP already used)
    "layers": [],                        # scan axis — never sharded
    "conv": [],
    "state": [],                         # SSM state dim
    # ---- activation dims ----
    "act_batch": [("pod", "data")],
    "act_seq": [],                       # attention-internal seq dim
    "act_res_seq": [],                   # residual stream between blocks;
                                         # ["model"] = Megatron sequence-parallel
    "act_embed": [],
    "act_heads": ["model"],
    "act_mlp": ["model"],
    "act_experts": ["model"],
    "act_kv": ["model"],
    "act_kv_seq": ["model"],             # decode-cache fallback chain kv -> kv_seq
    "act_head_dim": ["model"],
    "act_vocab": ["model"],
    "act_state": [],
}


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    table: Mapping[str, list]

    @classmethod
    def default(cls) -> "LogicalRules":
        return cls(dict(DEFAULT_RULES))

    def override(self, **updates) -> "LogicalRules":
        t = dict(self.table)
        t.update(updates)
        return LogicalRules(t)

    def candidates(self, name: str) -> list[AxisGroup]:
        return [_as_group(c) for c in self.table.get(name, [])]


def resolve_spec(names: Sequence[str | None], shape: Sequence[int],
                 mesh: Mesh, rules: LogicalRules) -> P:
    """First-fit resolution of logical dim names -> PartitionSpec."""
    if len(names) != len(shape):
        raise ValueError(f"names {names} vs shape {shape}")
    used: set[str] = set()
    out: list = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, dim in zip(names, shape):
        assigned = None
        if name is not None:
            for cand in rules.candidates(name):
                axes = tuple(a for a in cand if a in axis_sizes)
                if not axes or any(a in used for a in axes):
                    continue
                size = int(np.prod([axis_sizes[a] for a in axes]))
                if size > 1 and dim % size == 0:
                    assigned = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
                    break
        out.append(assigned)
    # trailing Nones can be dropped but keep explicit for readability
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Carries (mesh, rules) through model code."""

    mesh: Mesh
    rules: LogicalRules

    def spec(self, names: Sequence[str | None], shape: Sequence[int]) -> P:
        return resolve_spec(names, shape, self.mesh, self.rules)

    def sharding(self, names, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))

    def constrain(self, x: jax.Array, names: Sequence[str | None]) -> jax.Array:
        """with_sharding_constraint by logical names (no-op if fully replicated)."""
        spec = self.spec(names, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def tree_shardings(self, spec_tree) -> Any:
        """Map a tree of ParamSpec-likes (objects with .shape and .names) to
        NamedShardings."""
        return jax.tree_util.tree_map(
            lambda s: self.sharding(s.names, s.shape), spec_tree,
            is_leaf=lambda s: hasattr(s, "names"))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
