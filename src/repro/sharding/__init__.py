from .logical import (
    DEFAULT_RULES,
    LogicalRules,
    ShardingCtx,
    resolve_spec,
)

__all__ = ["DEFAULT_RULES", "LogicalRules", "ShardingCtx", "resolve_spec"]
