from .compression import (
    dequantize_int8,
    ef_compress,
    init_error_state,
    make_compressed_mean,
    quantize_int8,
)
from .elastic import MeshPlan, build_mesh, elastic_restore, remesh_plan
from .fault import Heartbeat, StragglerMonitor, with_retries
from .pipeline import bubble_fraction, pipeline_run

__all__ = ["Heartbeat", "MeshPlan", "StragglerMonitor", "bubble_fraction",
           "build_mesh", "dequantize_int8", "ef_compress", "elastic_restore",
           "init_error_state", "make_compressed_mean", "pipeline_run",
           "quantize_int8", "remesh_plan", "with_retries"]
