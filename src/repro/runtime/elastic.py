"""Elastic scaling: rebuild the mesh from surviving devices and reshard the
latest checkpoint onto it.

Checkpoints store full logical arrays (ckpt/checkpoint.py), so resharding is
restore + device_put under the new NamedShardings — no shard-file surgery.
The policy keeps the model (TP) axis fixed and shrinks/grows the data axis,
because optimizer state sharded over data re-balances for free while the
model axis is baked into layout choices.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import ckpt as ckpt_lib
from repro.sharding import LogicalRules, ShardingCtx


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    n_used: int
    n_available: int

    @property
    def utilization(self) -> float:
        return self.n_used / max(self.n_available, 1)


def remesh_plan(n_available: int, *, model: int = 16,
                axes=("data", "model")) -> MeshPlan:
    """Largest (data, model) mesh that fits the surviving device count."""
    if n_available < model:
        # degenerate: shrink the model axis to the largest power of two left
        m = 1 << (n_available.bit_length() - 1)
        return MeshPlan((1, m), axes, m, n_available)
    data = n_available // model
    return MeshPlan((data, model), axes, data * model, n_available)


def build_mesh(plan: MeshPlan):
    import jax
    n = int(np.prod(plan.shape))
    devs = np.array(jax.devices()[:n]).reshape(plan.shape)
    return jax.sharding.Mesh(devs, plan.axes)


def elastic_restore(ckpt_dir: str, plan: MeshPlan, model, opt,
                    rules: LogicalRules | None = None):
    """Restore the latest checkpoint resharded for the new mesh. Returns
    (params, opt_state, step, sctx)."""
    mesh = build_mesh(plan)
    sctx = ShardingCtx(mesh=mesh, rules=rules or LogicalRules.default())
    pspecs = model.param_specs()
    shardings = {
        "params": sctx.tree_shardings(pspecs),
        "opt": sctx.tree_shardings(opt.state_specs(pspecs)),
    }
    tree, step = ckpt_lib.restore(ckpt_dir, shardings=shardings)
    return tree["params"], tree["opt"], step, sctx
