"""Int8 error-feedback gradient compression for the slow (DCN / pod) axis.

Gradients crossing pods are quantized to int8 with a per-tensor absmax scale
before the cross-pod reduction (2x bytes vs bf16, 4x vs fp32), with error
feedback (the quantization residual is carried into the next step) so the
compression bias vanishes over time — the standard EF-SGD construction.

The reduction itself is expressed as all_gather(int8) + local sum inside
``shard_map`` (int8 psum would overflow; gathering the quantized operands
keeps the wire format int8, which is where the DCN win is).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(x: jax.Array, err: jax.Array):
    """Error-feedback quantize: returns (q, scale, new_err)."""
    target = x.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def make_compressed_mean(mesh: Mesh, axis: str):
    """Returns mean_c(stacked_tree, err_tree) -> (mean_tree, new_err_tree).

    ``stacked_tree`` leaves are (n_shards, ...) with the leading dim sharded
    over ``axis`` — each shard contributes its local gradient; the result is
    the int8-compressed mean, identical on every shard (leading dim kept).
    Error feedback is per-shard state carried across steps.
    """
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(tree, err_tree):
        def one(x, e):
            q, scale, new_e = ef_compress(x, e)
            qg = jax.lax.all_gather(q, axis)              # int8 on the wire
            sg = jax.lax.all_gather(scale, axis)
            deq = qg.astype(jnp.float32) * sg.reshape((n,) + (1,) * x.ndim)
            return jnp.sum(deq, axis=0) / n, new_e
        flat, treedef = jax.tree_util.tree_flatten(tree)
        flat_e = treedef.flatten_up_to(err_tree)
        out = [one(x, e) for x, e in zip(flat, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    def mean_c(stacked_tree, err_tree):
        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(axis), P(axis)), out_specs=(P(axis), P(axis)))
        return fn(stacked_tree, err_tree)

    return mean_c


def init_error_state(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)
