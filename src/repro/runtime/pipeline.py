"""GPipe-style pipeline parallelism over a mesh axis (the multi-pod "pod"
axis can be claimed as a stage axis instead of outer-DP; DESIGN.md §7).

Schedule: classic GPipe fill-drain with M microbatches over K stages
(bubble fraction (K-1)/(M+K-1)); the inter-stage hop is a single
``lax.ppermute`` (collective-permute on the wire — point-to-point, the only
collective the schedule needs).

Implemented with ``shard_map``: stage parameters are sharded over the axis
(leading dim = stage id); activations flow through the permute ring.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_run(mesh: Mesh, axis: str, stage_fn, stage_params, x_mb):
    """Run microbatches through a K-stage pipeline.

    stage_fn: (params_for_stage, x) -> y   (same shape as x)
    stage_params: pytree with leading dim K (sharded over ``axis``)
    x_mb: (M, mb, ...) microbatched input (replicated)

    Returns (M, mb, ...) outputs of the last stage.
    """
    K = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = x_mb.shape[0]
    T = M + K - 1                       # fill-drain schedule length
    perm = [(i, i + 1) for i in range(K - 1)]

    def local(params, xs):
        # params: (1, ...) this stage's slice; xs: (M, mb, ...) replicated
        p = jax.tree_util.tree_map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)

        def step(carry, t):
            buf, outs = carry           # buf: (mb, ...) incoming activation
            # stage 0 ingests microbatch t (when valid), others take buf
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(idx == 0, xs[mb_idx], buf)
            y = stage_fn(p, x_in)
            # last stage emits microbatch t - (K - 1)
            out_idx = jnp.clip(t - (K - 1), 0, M - 1)
            valid = (t >= K - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[out_idx].set(y),
                lambda o: o,
                outs)
            buf_next = jax.lax.ppermute(y, axis, perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(step, (buf0, outs0), jnp.arange(T))
        # only the last stage's collection is meaningful; replicate it
        outs = jnp.where(idx == K - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_mb)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
