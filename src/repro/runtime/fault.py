"""Fault-tolerance runtime: straggler detection, retry wrapper, heartbeats.

At 1000+ nodes the failure model is: slow host (straggler), dead host
(heartbeat timeout), transient error (preemption/network). The remedies wired
into ``launch/train.py``:
  * transient  -> ``with_retries`` around the step,
  * straggler  -> ``StragglerMonitor`` flags; remedy = elastic re-mesh
                  without the slow host (runtime/elastic.py),
  * dead host  -> heartbeat timeout -> restart from the latest committed
                  checkpoint (ckpt/ is atomic + auto-resume).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags hosts persistently slower than the
    fleet median by ``threshold``x."""

    threshold: float = 1.5
    alpha: float = 0.2
    patience: int = 5

    def __post_init__(self):
        self._ewma: dict[int, float] = {}
        self._strikes: dict[int, int] = {}

    def update(self, host_times: dict[int, float]) -> list[int]:
        for h, t in host_times.items():
            prev = self._ewma.get(h, t)
            self._ewma[h] = (1 - self.alpha) * prev + self.alpha * t
        if len(self._ewma) < 2:
            return []
        med = sorted(self._ewma.values())[len(self._ewma) // 2]
        flagged = []
        for h, v in self._ewma.items():
            if v > self.threshold * med:
                self._strikes[h] = self._strikes.get(h, 0) + 1
                if self._strikes[h] >= self.patience:
                    flagged.append(h)
            else:
                self._strikes[h] = 0
        return flagged


def with_retries(fn: Callable, *, max_retries: int = 3, backoff_s: float = 0.5,
                 retriable=(RuntimeError, OSError), on_retry=None):
    """Wrap a step function against transient failures."""
    def wrapped(*a, **kw):
        err = None
        for attempt in range(max_retries + 1):
            try:
                return fn(*a, **kw)
            except retriable as e:   # pragma: no cover - timing dependent
                err = e
                if on_retry:
                    on_retry(attempt, e)
                time.sleep(backoff_s * (2 ** attempt))
        raise err
    return wrapped


class Heartbeat:
    """File-based liveness: each host touches its file; the coordinator
    treats silence > timeout as host death (triggering elastic restart)."""

    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"host_{host_id}.hb")
        os.makedirs(directory, exist_ok=True)
        self.host_id = host_id

    def beat(self, step: int):
        with open(self.path, "w") as f:
            json.dump({"step": step, "t": time.time()}, f)

    @staticmethod
    def dead_hosts(directory: str, timeout_s: float) -> list[int]:
        now = time.time()
        dead = []
        if not os.path.isdir(directory):
            return dead
        for fn in os.listdir(directory):
            if not fn.endswith(".hb"):
                continue
            try:
                with open(os.path.join(directory, fn)) as f:
                    t = json.load(f)["t"]
            except Exception:
                t = 0
            if now - t > timeout_s:
                dead.append(int(fn.split("_")[1].split(".")[0]))
        return sorted(dead)
