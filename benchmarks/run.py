"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall-times are CPU-host times
(this container has no TPU): the *relative* constant-vs-batch trends mirror
the paper's Figs. 2-4 mechanism (less memory traffic per solve); the
absolute roofline story for TPU lives in EXPERIMENTS.md §Roofline and the
analytic kernel-traffic table (bench_kernel_traffic).

Solver benchmarks route through the unified ``repro.solver`` front-end, so
constant-vs-batch × reference-vs-pallas is one sweep (``backends`` table).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig2       # one table
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_solvers.json

``--json`` additionally writes ``BENCH_solvers.json`` — a list of
``{name, us_per_call, backend, n, m}`` rows (the ``backends`` sweep, penta
``batch``-mode rows included, the ``grad_solve`` rows timing the
custom_vjp adjoint, and the ``recurrence`` rows timing the sequence-model
substrate) — so the perf trajectory is machine-readable across PRs.
Kernel-backed rows also carry ``model_bytes`` (the spec-derived expected
HBM traffic) plus the ``traffic`` key it was resolved from; the regress
gate re-derives the number from the live registry, so a traffic-model
drift fails CI exactly like a timing regression.  CI runs ``--json`` in
interpret mode on every push, then diffs the rows against the committed
baseline with ``tools/bench_regress.py``, so the perf plumbing cannot
silently rot and a matched row cannot silently get 1.5x slower.
"""

from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

JSON_PATH = "BENCH_solvers.json"
_ROWS: list = []   # machine-readable mirror of the printed CSV


def _record(name: str, us_per_call: float, *, backend=None, n=None, m=None,
            derived: str = "", traffic: dict | None = None):
    """``traffic`` is the spec-resolver key (bandwidth/mode/streamed/fused/
    storage_dtype, or order/reverse for recurrences); when present the row
    also carries ``model_bytes`` — the expected HBM traffic re-derived by
    ``tools/bench_regress.py`` from the same key, so a drifted traffic
    model fails the bench gate."""
    row = {"name": name, "us_per_call": round(us_per_call, 1),
           "backend": backend, "n": n, "m": m}
    if traffic is not None:
        row["model_bytes"] = _model_bytes(traffic, n, m)
        row["traffic"] = traffic
        derived = (derived + "_" if derived else "") \
            + f"model_bytes={row['model_bytes']}"
    print(f"{name},{us_per_call:.0f},{derived}")
    _ROWS.append(row)


def _model_bytes(traffic: dict, n: int, m: int) -> int:
    """Resolve a row's traffic key through the kernel spec registry."""
    from repro.kernels import ops as kops
    key = dict(traffic)
    if "order" in key:
        return kops.recurrence_hbm_traffic_bytes(key.pop("order"), n, m,
                                                 **key)
    return kops.solver_hbm_traffic_bytes(key.pop("bandwidth"),
                                         key.pop("mode"), n, m, **key)


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6   # us


def _rhs(n, m, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))


# ---------------------------------------------------------------------------
# Fig. 2: tridiagonal — cuThomasConstantBatch vs cuThomasBatch (N x M grid)
# ---------------------------------------------------------------------------

def bench_fig2_tridiag():
    from repro.solver import BandedSystem, plan
    sigma = 0.4
    for n in (64, 256, 1024):
        for m in (64, 512, 4096):
            ops = {}
            for mode in ("constant", "batch"):
                p = plan(BandedSystem.tridiag(
                    -sigma, 1 + 2 * sigma, -sigma, n=n, mode=mode,
                    periodic=True, batch=m if mode == "batch" else None),
                    backend="reference")
                d = _rhs(n, m)
                ops[mode] = _timeit(jax.jit(p.solve), d)
            speedup = ops["batch"] / ops["constant"]
            _record(f"fig2_tridiag_N{n}_M{m}", ops["constant"],
                    backend="reference", n=n, m=m,
                    derived=f"speedup_vs_batch={speedup:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 3: pentadiagonal — cuPentConstantBatch vs cuPentBatch
# ---------------------------------------------------------------------------

def bench_fig3_penta():
    from repro.solver import BandedSystem, plan
    s = 0.11
    coef = (s, -4 * s, 1 + 6 * s, -4 * s, s)
    for n in (64, 256, 1024):
        for m in (64, 512, 4096):
            res = {}
            for mode in ("constant", "batch"):
                p = plan(BandedSystem.penta(
                    *coef, n=n, mode=mode, periodic=True,
                    batch=m if mode == "batch" else None),
                    backend="reference")
                d = _rhs(n, m)
                res[mode] = _timeit(jax.jit(p.solve), d)
            _record(f"fig3_penta_N{n}_M{m}", res["constant"],
                    backend="reference", n=n, m=m,
                    derived=f"speedup_vs_batch={res['batch']/res['constant']:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 4: cuPentUniformBatch vs cuPentBatch
# ---------------------------------------------------------------------------

def bench_fig4_uniform():
    from repro.solver import BandedSystem, plan
    s = 0.11
    coef = (s, -4 * s, 1 + 6 * s, -4 * s, s)
    for n, m in ((256, 512), (1024, 512), (256, 4096)):
        res = {}
        for mode in ("uniform", "batch"):
            p = plan(BandedSystem.penta(
                *coef, n=n, mode=mode, periodic=True,
                batch=m if mode == "batch" else None), backend="reference")
            d = _rhs(n, m)
            res[mode] = _timeit(jax.jit(p.solve), d)
        _record(f"fig4_uniform_N{n}_M{m}", res["uniform"],
                backend="reference", n=n, m=m,
                derived=f"speedup_vs_batch={res['batch']/res['uniform']:.2f}x")


# ---------------------------------------------------------------------------
# Storage table (§III.A / §IV.A claims: ~75% and ~83% reductions)
# ---------------------------------------------------------------------------

def bench_memory_table():
    from repro.solver import BandedSystem, plan
    n, m = 1024, 65536

    def total(system):
        return plan(system, backend="reference").storage_bytes(
            rhs_batch=m)["total_bytes"]

    tc = total(BandedSystem.tridiag(1., 4., 1., n=n, mode="constant"))
    tb = total(BandedSystem.tridiag(1., 4., 1., n=n, mode="batch", batch=m))
    print(f"mem_tridiag_N{n}_M{m},0,reduction={100*(1-tc/tb):.1f}%_paper75%")
    pen = (1., -4., 7., -4., 1.)
    pc = total(BandedSystem.penta(*pen, n=n, mode="constant"))
    pb = total(BandedSystem.penta(*pen, n=n, mode="batch", batch=m))
    pu = total(BandedSystem.penta(*pen, n=n, mode="uniform"))
    print(f"mem_penta_N{n}_M{m},0,reduction={100*(1-pc/pb):.1f}%_paper83%")
    print(f"mem_penta_uniform_N{n}_M{m},0,reduction={100*(1-pu/pb):.1f}%")


# ---------------------------------------------------------------------------
# Kernel HBM-traffic table (the TPU roofline story for the Pallas kernels)
# ---------------------------------------------------------------------------

def bench_kernel_traffic():
    from repro.kernels.fused_cn import hbm_traffic_bytes as fused_t
    from repro.kernels.fused_cn_penta import hbm_traffic_bytes as fusedp_t
    from repro.kernels.penta import hbm_traffic_bytes as pen_t
    from repro.kernels.thomas import hbm_traffic_bytes as tri_t
    n, m = 1024, 65536
    t = tri_t(n, m)
    print(f"traffic_tridiag_N{n}_M{m},0,batch/constant="
          f"{t['batch']/t['constant']:.2f}x")
    print(f"traffic_tridiag_streamed_N{n}_M{m},0,streamed/constant="
          f"{t['constant_streamed']/t['constant']:.2f}x_still_"
          f"{t['batch']/t['constant_streamed']:.2f}x_under_batch")
    print(f"traffic_tridiag_batch_streamed_N{n}_M{m},0,streamed/resident="
          f"{t['batch_streamed']/t['batch']:.2f}x_spilled_chat")
    p = pen_t(n, m)
    print(f"traffic_penta_N{n}_M{m},0,batch/constant="
          f"{p['batch']/p['constant']:.2f}x")
    print(f"traffic_penta_streamed_N{n}_M{m},0,streamed/constant="
          f"{p['constant_streamed']/p['constant']:.2f}x_still_"
          f"{p['batch']/p['constant_streamed']:.2f}x_under_batch")
    print(f"traffic_penta_batch_streamed_N{n}_M{m},0,streamed/resident="
          f"{p['batch_streamed']/p['batch']:.2f}x_spilled_gamma_delta")
    fz = fused_t(n, m)
    print(f"traffic_fused_cn_N{n}_M{m},0,unfused/fused="
          f"{fz['unfused_pipeline']/fz['fused']:.2f}x")
    fp = fusedp_t(n, m)
    print(f"traffic_fused_cn_penta_N{n}_M{m},0,unfused/fused="
          f"{fp['unfused_pipeline']/fp['fused']:.2f}x")
    # memory-roofline seconds per CN step on v5e (819 GB/s)
    for name, b in [("constant_pipeline", fz["unfused_pipeline"]),
                    ("fused", fz["fused"]),
                    ("penta_fused", fp["fused"])]:
        print(f"roofline_cn_step_{name},{b/819e9*1e6:.1f},hbm_bound_us")


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs pure-jnp reference — correctness + time
# ---------------------------------------------------------------------------

def bench_pallas_kernels():
    from repro.core import thomas_factor
    from repro.kernels import thomas_constant
    n, m = 256, 1024
    rng = np.random.default_rng(0)
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    d = _rhs(n, m)
    t = _timeit(lambda dd: thomas_constant(f, dd), d, reps=2)
    _record(f"pallas_thomas_constant_interp_N{n}_M{m}", t, backend="pallas",
            n=n, m=m, derived="interpret_mode")


# ---------------------------------------------------------------------------
# Backend axis: constant-vs-batch x reference-vs-pallas through repro.solver
# ---------------------------------------------------------------------------

def bench_backends():
    """One sweep over the repro.solver registry: the benchmark surface later
    PRs extend when they plug new backends in. (Pallas rows are interpret
    mode off-TPU — compare trends, not absolutes.)"""
    from repro.solver import BandedSystem, plan
    sigma = 0.4
    n, m = 256, 512
    d = _rhs(n, m)
    for mode in ("constant", "batch"):
        for backend in ("reference", "pallas"):
            p = plan(BandedSystem.tridiag(
                -sigma, 1 + 2 * sigma, -sigma, n=n, mode=mode,
                batch=m if mode == "batch" else None), backend=backend)
            t = _timeit(jax.jit(p.solve), d, reps=2)
            _record(f"solver_tridiag_{mode}_{backend}_N{n}_M{m}", t,
                    backend=backend, n=n, m=m, derived=f"mode={mode}",
                    traffic={"bandwidth": 3, "mode": mode}
                    if backend == "pallas" else None)
    s = 0.11
    for mode in ("constant", "batch"):
        for backend in ("reference", "pallas"):
            p = plan(BandedSystem.penta(
                s, -4 * s, 1 + 6 * s, -4 * s, s, n=n, mode=mode,
                batch=m if mode == "batch" else None), backend=backend)
            t = _timeit(jax.jit(p.solve), d, reps=2)
            _record(f"solver_penta_{mode}_{backend}_N{n}_M{m}", t,
                    backend=backend, n=n, m=m, derived=f"mode={mode}",
                    traffic={"bandwidth": 5, "mode": mode}
                    if backend == "pallas" else None)
    bench_backends_streamed()


def bench_backends_streamed():
    """Large-N rows in the regime the HBM-streamed split-N kernels unlock:
    at N=16384 the resident pallas working set exceeds the VMEM budget at
    EVERY block_m candidate (even 128 needs 16 MiB), so before PR 3
    ``auto`` could only fall back to reference here.  ``auto`` now
    resolves to pallas with a streamed ``block_n`` AND the fused
    single-call sweeps (the full-N scratch fits at block_m=128) — both
    asserted below, so neither the fallback nor the two-call spill can
    silently return.  The ``bf16s`` rows store factor + streamed RHS at
    bf16 in HBM (carries stay fp32), halving the stored-operand bytes."""
    from repro.solver import BandedSystem, plan
    n, m = 16384, 1024
    d = _rhs(n, m)
    sigma = 0.4
    tri = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n)
    s = 0.11
    pen = BandedSystem.penta(s, -4 * s, 1 + 6 * s, -4 * s, s, n=n)
    for kind, bw, system in (("tridiag", 3, tri), ("penta", 5, pen)):
        for backend in ("reference", "auto"):
            p = plan(system, backend=backend)
            if backend == "auto":
                assert p.backend == "pallas", "streamed auto-select regressed"
                block_n = p.impl.block_n
                assert block_n is not None, "expected the streamed kernels"
                assert p.impl.fact.meta.opt("fused") is True, \
                    "auto no longer selects the fused single-call sweeps"
                label = "pallas"
                derived = f"fused_block_n={block_n}"
                traffic = {"bandwidth": bw, "mode": "constant",
                           "streamed": True, "fused": True}
            else:
                label, derived, traffic = backend, "mode=constant", None
            t = _timeit(jax.jit(p.solve), d, reps=2)
            _record(f"solver_{kind}_constant_{label}_fused_streamed_N{n}_M{m}"
                    if backend == "auto" else
                    f"solver_{kind}_constant_{label}_N{n}_M{m}", t,
                    backend=label, n=n, m=m, derived=derived, traffic=traffic)
        # mixed-precision storage on the same fused streamed point
        p = plan(system, backend="pallas", storage_dtype="bf16")
        assert p.impl.fact.meta.opt("storage_dtype") == "bfloat16"
        t = _timeit(jax.jit(p.solve), d, reps=2)
        _record(f"solver_{kind}_constant_pallas_bf16s_streamed_N{n}_M{m}", t,
                backend="pallas", n=n, m=m,
                derived=f"storage=bf16_fused={p.impl.fact.meta.opt('fused')}",
                traffic={"bandwidth": bw, "mode": "constant",
                         "streamed": True,
                         "fused": bool(p.impl.fact.meta.opt("fused")),
                         "storage_dtype": "bf16"})
    bench_batch_streamed()


def bench_batch_streamed():
    """mode="batch" past the old VMEM wall: before the sweep engine the
    per-lane diagonal blocks could not stream, so ``auto`` fell back to
    reference at this N.  The engine's batch-streamed pair (fused-factor
    scratch spilled to HBM between the passes) keeps pallas in play —
    asserted, so the fallback cannot silently return."""
    from repro.solver import BandedSystem, plan
    n, m = 16384, 1024
    d = _rhs(n, m)
    sigma = 0.4
    system = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n,
                                  mode="batch", batch=m)
    for backend in ("reference", "auto"):
        p = plan(system, backend=backend)
        if backend == "auto":
            assert p.backend == "pallas", "batch streamed auto-select regressed"
            block_n = p.impl.block_n
            assert block_n is not None, "expected the batch streamed kernels"
            # the batch fused working set (two full-N sweep scratches)
            # exceeds the VMEM budget here: the tuner must SPILL to the
            # two-call pair, not reject the solve
            assert p.impl.fact.meta.opt("fused") is False, \
                "batch fused spill rule regressed"
            label, derived = "pallas", f"batch_streamed_block_n={block_n}"
            traffic = {"bandwidth": 3, "mode": "batch", "streamed": True}
        else:
            label, derived, traffic = backend, "mode=batch", None
        t = _timeit(jax.jit(p.solve), d, reps=2)
        _record(f"solver_tridiag_batch_{label}_streamed_N{n}_M{m}"
                if backend == "auto" else
                f"solver_tridiag_batch_{label}_N{n}_M{m}", t,
                backend=label, n=n, m=m, derived=derived, traffic=traffic)
    bench_sharded()


def bench_sharded():
    """The sharded x streamed composition: the ``sharded`` backend running
    the engine's Pallas kernels per device inside shard_map (vs the old
    per-shard reference sweeps, kept as the ``kernels="reference"`` row).
    The engine dispatch is asserted so the composition cannot silently
    degrade back to reference sweeps."""
    from repro.solver import BandedSystem, plan
    sigma = 0.4
    n, m = 256, 512
    d = _rhs(n, m)
    system = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n)
    for kernels in ("reference", "auto"):
        p = plan(system, backend="sharded", kernels=kernels)
        if kernels == "auto":
            assert p.impl.kernels == "pallas", "sharded kernel dispatch regressed"
        label = p.impl.kernels
        t = _timeit(jax.jit(p.solve), d, reps=2)
        _record(f"solver_tridiag_constant_sharded_{label}_N{n}_M{m}", t,
                backend="sharded", n=n, m=m,
                derived=f"shards={p.impl.n_shards}_kernels={label}")
    # large-N: streamed split-N chunks per shard (block_n frozen in meta)
    n = 16384
    d = _rhs(n, m)
    p = plan(BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n),
             backend="sharded")
    assert p.impl.kernels == "pallas", "sharded kernel dispatch regressed"
    assert p.impl.block_n is not None, "expected streamed kernels per shard"
    t = _timeit(jax.jit(p.solve), d, reps=2)
    _record(f"solver_tridiag_constant_sharded_streamed_N{n}_M{m}", t,
            backend="sharded", n=n, m=m,
            derived=f"shards={p.impl.n_shards}_block_n={p.impl.block_n}")


# ---------------------------------------------------------------------------
# Differentiable solves: the custom_vjp adjoint (transposed solve reusing
# the forward factorization) through the pure factorize/solve API
# ---------------------------------------------------------------------------

def bench_grad_solve():
    """Time jax.grad through ``solve`` — the adjoint is one transposed
    banded solve on the SAME stored factor, so grad should cost ~2x the
    forward solve, not a refactor + dense VJP."""
    from repro.solver import BandedSystem, factorize, solve
    n, m = 256, 512
    d = _rhs(n, m)
    sigma = 0.4
    systems = {
        "tridiag": BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n,
                                        periodic=True),
        "penta": BandedSystem.penta(0.11, -0.44, 1.66, -0.44, 0.11, n=n,
                                    periodic=True),
    }
    for kind, system in systems.items():
        fact = factorize(system, backend="reference")
        fwd = _timeit(jax.jit(lambda r: solve(fact, r)), d, reps=2)
        g = jax.jit(jax.grad(lambda r: jnp.sum(solve(fact, r) ** 2)))
        t = _timeit(g, d, reps=2)
        _record(f"grad_solve_{kind}_reference_N{n}_M{m}", t,
                backend="reference", n=n, m=m,
                derived=f"grad/fwd={t / fwd:.2f}x_adjoint_reuses_factor")
    bench_grad_solve_streamed()


def bench_grad_solve_streamed():
    """grad through a LARGE-N streamed solve: the adjoint runs the sweep
    engine's streamed TRANSPOSED Pallas kernels on the same stored factor
    (no reference fallback — asserted via the auto-tuned streamed plan)."""
    from repro.solver import BandedSystem, factorize, solve
    n, m = 16384, 1024
    d = _rhs(n, m)
    sigma = 0.4
    system = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=n)
    fact = factorize(system, backend="auto")
    assert fact.backend == "pallas", "streamed auto-select regressed"
    assert fact.meta.opt("block_n") is not None, "expected streamed kernels"
    fwd = _timeit(jax.jit(lambda r: solve(fact, r)), d, reps=2)
    g = jax.jit(jax.grad(lambda r: jnp.sum(solve(fact, r) ** 2)))
    t = _timeit(g, d, reps=2)
    _record(f"grad_solve_streamed_tridiag_pallas_N{n}_M{m}", t,
            backend="pallas", n=n, m=m,
            derived=f"grad/fwd={t / fwd:.2f}x_adjoint_on_streamed_pallas")


# ---------------------------------------------------------------------------
# Gated linear recurrences: XLA scan vs the engine's Pallas kernels
# ---------------------------------------------------------------------------

def bench_recurrence():
    """The sequence-model substrate (``repro.core.recurrence``): first- and
    second-order gated recurrences, XLA scan vs the sweep engine's Pallas
    recurrence kernels (interpret mode off-TPU — compare trends, not
    absolutes), plus a forced streamed row.  The auto policy is asserted
    so the models' kernel path cannot silently degrade back to scan."""
    from repro.core.recurrence import (_resolve, linear_recurrence,
                                       linear_recurrence2)
    from repro.kernels import recurrence_hbm_traffic_bytes
    assert _resolve("auto", jnp.float32) == "pallas", "auto policy regressed"
    n, m = 1024, 512
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.uniform(-0.9, 0.9, (n, m)).astype(np.float32))
    s = jnp.asarray(rng.uniform(-0.6, 0.6, (n, m)).astype(np.float32))
    t2 = jnp.asarray(rng.uniform(-0.3, 0.3, (n, m)).astype(np.float32))
    q = _rhs(n, m)
    for method in ("scan", "pallas"):
        t = _timeit(jax.jit(
            lambda d: linear_recurrence(p, d, method=method)), q, reps=2)
        _record(f"recurrence_order1_{method}_N{n}_M{m}", t, backend=method,
                n=n, m=m, traffic={"order": 1} if method == "pallas"
                else None,
                derived=f"hbm_bytes={recurrence_hbm_traffic_bytes(1, n, m)}")
        t = _timeit(jax.jit(
            lambda d: linear_recurrence2(s, t2, d, method=method)), q, reps=2)
        _record(f"recurrence_order2_{method}_N{n}_M{m}", t, backend=method,
                n=n, m=m, traffic={"order": 2} if method == "pallas"
                else None,
                derived=f"hbm_bytes={recurrence_hbm_traffic_bytes(2, n, m)}")
    # forced streamed kernel: same arithmetic, chunked sweep residency
    t = _timeit(jax.jit(
        lambda d: linear_recurrence(p, d, method="pallas", block_n=256)),
        q, reps=2)
    _record(f"recurrence_order1_pallas_streamed_N{n}_M{m}", t,
            backend="pallas", n=n, m=m,
            traffic={"order": 1, "streamed": True},
            derived="block_n=256")


# ---------------------------------------------------------------------------
# Dry-run roofline summary (reads artifacts if present)
# ---------------------------------------------------------------------------

def bench_dryrun_summary():
    import glob
    import json
    import os
    rows = []
    for p in sorted(glob.glob("artifacts/dryrun/*.json")):
        if "__pod2" in p or "__" not in os.path.basename(p):
            continue
        d = json.load(open(p))
        if d.get("status") != "ok":
            continue
        rl = d["roofline"]
        rows.append((d["arch"], d["shape"], rl["dominant"],
                     rl["bound_s"], d.get("roofline_fraction", 0)))
    if not rows:
        print("dryrun_summary,0,no_artifacts_run_python_-m_repro.launch.dryrun_--all")
        return
    for arch, shape, dom, bound, frac in rows:
        print(f"dryrun_{arch}_{shape},{bound*1e6:.0f},"
              f"dominant={dom}_rooflinefrac={frac:.3f}")


TABLES = {
    "fig2": bench_fig2_tridiag,
    "fig3": bench_fig3_penta,
    "fig4": bench_fig4_uniform,
    # bench_backends_streamed / bench_batch_streamed / bench_sharded chain
    # off "backends", and bench_grad_solve_streamed off "grad" — not
    # registered separately, so selecting several tables never records
    # duplicate rows.
    "backends": bench_backends,
    "grad": bench_grad_solve,
    "recurrence": bench_recurrence,
    "memory": bench_memory_table,
    "traffic": bench_kernel_traffic,
    "pallas": bench_pallas_kernels,
    "dryrun": bench_dryrun_summary,
}


def main() -> None:
    argv = sys.argv[1:]
    write_json = "--json" in argv
    which = [a for a in argv if not a.startswith("--")]
    if not which:
        # --json alone: the solver tables that carry (backend, n, m) rows.
        which = (["backends", "grad", "recurrence"] if write_json
                 else list(TABLES))
    print("name,us_per_call,derived")
    for k in which:
        TABLES[k]()
    if write_json:
        with open(JSON_PATH, "w") as fh:
            json.dump(_ROWS, fh, indent=2)
        print(f"# wrote {len(_ROWS)} rows to {JSON_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
