"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall-times are CPU-host times
(this container has no TPU): the *relative* constant-vs-batch trends mirror
the paper's Figs. 2-4 mechanism (less memory traffic per solve); the
absolute roofline story for TPU lives in EXPERIMENTS.md §Roofline and the
analytic kernel-traffic table (bench_kernel_traffic).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig2       # one table
"""

from __future__ import annotations

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6   # us


def _rhs(n, m, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))


# ---------------------------------------------------------------------------
# Fig. 2: tridiagonal — cuThomasConstantBatch vs cuThomasBatch (N x M grid)
# ---------------------------------------------------------------------------

def bench_fig2_tridiag():
    from repro.core import TridiagOperator
    sigma = 0.4
    for n in (64, 256, 1024):
        for m in (64, 512, 4096):
            ops = {}
            for mode in ("constant", "batch"):
                op = TridiagOperator.create(
                    -sigma, 1 + 2 * sigma, -sigma, n=n, mode=mode,
                    periodic=True, batch=m if mode == "batch" else None)
                d = _rhs(n, m)
                f = jax.jit(op.solve)
                ops[mode] = _timeit(f, d)
            speedup = ops["batch"] / ops["constant"]
            print(f"fig2_tridiag_N{n}_M{m},{ops['constant']:.0f},"
                  f"speedup_vs_batch={speedup:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 3: pentadiagonal — cuPentConstantBatch vs cuPentBatch
# ---------------------------------------------------------------------------

def bench_fig3_penta():
    from repro.core import PentaOperator
    s = 0.11
    coef = (s, -4 * s, 1 + 6 * s, -4 * s, s)
    for n in (64, 256, 1024):
        for m in (64, 512, 4096):
            res = {}
            for mode in ("constant", "batch"):
                op = PentaOperator.create(
                    *coef, n=n, mode=mode, periodic=True,
                    batch=m if mode == "batch" else None)
                d = _rhs(n, m)
                res[mode] = _timeit(jax.jit(op.solve), d)
            print(f"fig3_penta_N{n}_M{m},{res['constant']:.0f},"
                  f"speedup_vs_batch={res['batch']/res['constant']:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 4: cuPentUniformBatch vs cuPentBatch
# ---------------------------------------------------------------------------

def bench_fig4_uniform():
    from repro.core import PentaOperator
    s = 0.11
    coef = (s, -4 * s, 1 + 6 * s, -4 * s, s)
    for n, m in ((256, 512), (1024, 512), (256, 4096)):
        res = {}
        for mode in ("uniform", "batch"):
            op = PentaOperator.create(
                *coef, n=n, mode=mode, periodic=True,
                batch=m if mode == "batch" else None)
            d = _rhs(n, m)
            res[mode] = _timeit(jax.jit(op.solve), d)
        print(f"fig4_uniform_N{n}_M{m},{res['uniform']:.0f},"
              f"speedup_vs_batch={res['batch']/res['uniform']:.2f}x")


# ---------------------------------------------------------------------------
# Storage table (§III.A / §IV.A claims: ~75% and ~83% reductions)
# ---------------------------------------------------------------------------

def bench_memory_table():
    from repro.core import PentaOperator, TridiagOperator
    n, m = 1024, 65536
    tri_c = TridiagOperator.create(1., 4., 1., n=n, mode="constant")
    tri_b = TridiagOperator.create(1., 4., 1., n=n, mode="batch", batch=m)
    tc = tri_c.storage_bytes(rhs_batch=m)["total_bytes"]
    tb = tri_b.storage_bytes(rhs_batch=m)["total_bytes"]
    print(f"mem_tridiag_N{n}_M{m},0,reduction={100*(1-tc/tb):.1f}%_paper75%")
    pen_c = PentaOperator.create(1., -4., 7., -4., 1., n=n, mode="constant")
    pen_b = PentaOperator.create(1., -4., 7., -4., 1., n=n, mode="batch", batch=m)
    pen_u = PentaOperator.create(1., -4., 7., -4., 1., n=n, mode="uniform")
    pc = pen_c.storage_bytes(rhs_batch=m)["total_bytes"]
    pb = pen_b.storage_bytes(rhs_batch=m)["total_bytes"]
    pu = pen_u.storage_bytes(rhs_batch=m)["total_bytes"]
    print(f"mem_penta_N{n}_M{m},0,reduction={100*(1-pc/pb):.1f}%_paper83%")
    print(f"mem_penta_uniform_N{n}_M{m},0,reduction={100*(1-pu/pb):.1f}%")


# ---------------------------------------------------------------------------
# Kernel HBM-traffic table (the TPU roofline story for the Pallas kernels)
# ---------------------------------------------------------------------------

def bench_kernel_traffic():
    from repro.kernels.fused_cn import hbm_traffic_bytes as fused_t
    from repro.kernels.fused_cn_penta import hbm_traffic_bytes as fusedp_t
    from repro.kernels.penta import hbm_traffic_bytes as pen_t
    from repro.kernels.thomas import hbm_traffic_bytes as tri_t
    n, m = 1024, 65536
    t = tri_t(n, m)
    print(f"traffic_tridiag_N{n}_M{m},0,batch/constant="
          f"{t['batch']/t['constant']:.2f}x")
    p = pen_t(n, m)
    print(f"traffic_penta_N{n}_M{m},0,batch/constant="
          f"{p['batch']/p['constant']:.2f}x")
    fz = fused_t(n, m)
    print(f"traffic_fused_cn_N{n}_M{m},0,unfused/fused="
          f"{fz['unfused_pipeline']/fz['fused']:.2f}x")
    fp = fusedp_t(n, m)
    print(f"traffic_fused_cn_penta_N{n}_M{m},0,unfused/fused="
          f"{fp['unfused_pipeline']/fp['fused']:.2f}x")
    # memory-roofline seconds per CN step on v5e (819 GB/s)
    for name, b in [("constant_pipeline", fz["unfused_pipeline"]),
                    ("fused", fz["fused"]),
                    ("penta_fused", fp["fused"])]:
        print(f"roofline_cn_step_{name},{b/819e9*1e6:.1f},hbm_bound_us")


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs pure-jnp reference — correctness + time
# ---------------------------------------------------------------------------

def bench_pallas_kernels():
    from repro.core import thomas_factor
    from repro.kernels import thomas_constant
    n, m = 256, 1024
    rng = np.random.default_rng(0)
    a = rng.uniform(-1, 1, n).astype(np.float32)
    c = rng.uniform(-1, 1, n).astype(np.float32)
    b = (np.abs(a) + np.abs(c) + 2.5).astype(np.float32)
    f = thomas_factor(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    d = _rhs(n, m)
    t = _timeit(lambda dd: thomas_constant(f, dd), d, reps=2)
    print(f"pallas_thomas_constant_interp_N{n}_M{m},{t:.0f},interpret_mode")


# ---------------------------------------------------------------------------
# Dry-run roofline summary (reads artifacts if present)
# ---------------------------------------------------------------------------

def bench_dryrun_summary():
    import glob
    import json
    import os
    rows = []
    for p in sorted(glob.glob("artifacts/dryrun/*.json")):
        if "__pod2" in p or "__" not in os.path.basename(p):
            continue
        d = json.load(open(p))
        if d.get("status") != "ok":
            continue
        rl = d["roofline"]
        rows.append((d["arch"], d["shape"], rl["dominant"],
                     rl["bound_s"], d.get("roofline_fraction", 0)))
    if not rows:
        print("dryrun_summary,0,no_artifacts_run_python_-m_repro.launch.dryrun_--all")
        return
    for arch, shape, dom, bound, frac in rows:
        print(f"dryrun_{arch}_{shape},{bound*1e6:.0f},"
              f"dominant={dom}_rooflinefrac={frac:.3f}")


TABLES = {
    "fig2": bench_fig2_tridiag,
    "fig3": bench_fig3_penta,
    "fig4": bench_fig4_uniform,
    "memory": bench_memory_table,
    "traffic": bench_kernel_traffic,
    "pallas": bench_pallas_kernels,
    "dryrun": bench_dryrun_summary,
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for k in which:
        TABLES[k]()


if __name__ == "__main__":
    main()
