"""Paper §IV benchmark problem: batches of periodic 1-D hyperdiffusion
equations (Cahn-Hilliard-like), Crank-Nicolson, comparing cuPentBatch-
equivalent (per-system LHS) vs cuPentConstantBatch vs cuPentUniformBatch —
the Fig. 3 / Fig. 4 setting.

    PYTHONPATH=src python examples/hyperdiffusion_1d.py [--steps 200]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.pde import HyperdiffusionCN

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--n", type=int, default=128)
ap.add_argument("--m", type=int, default=256)
args = ap.parse_args()

N, M, steps = args.n, args.m, args.steps
dt = 1e-7
x = np.arange(N) / N
f0 = jnp.asarray(np.tile(np.sin(2 * np.pi * x)[:, None], (1, M))
                 .astype(np.float32))

print(f"hyperdiffusion: N={N} M={M} steps={steps} (paper Figs. 3-4 problem)")
results = {}
for mode in ["batch", "constant", "uniform"]:
    model = HyperdiffusionCN(n=N, dt=dt, mode=mode,
                             batch=M if mode == "batch" else None)
    run = jax.jit(lambda f: model.run(f, steps))
    jax.block_until_ready(run(f0))
    t0 = time.time()
    out = np.asarray(jax.block_until_ready(run(f0)))
    wall = time.time() - t0
    want = model.analytic(x, dt * steps)[:, None]
    err = np.max(np.abs(out - want))
    results[mode] = wall
    label = {"batch": "cuPentBatch-equiv (per-system LHS)",
             "constant": "cuPentConstantBatch",
             "uniform": "cuPentUniformBatch"}[mode]
    print(f"  {label:38s} {wall:7.2f} s   err {err:.2e}")
print(f"speed-up constant vs per-system: {results['batch']/results['constant']:.2f}x"
      f"   uniform vs per-system: {results['batch']/results['uniform']:.2f}x")
print("OK")
