"""Serving example: batched prefill + decode with continuous-batching-lite
(thin wrapper over repro.launch.serve).

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "granite-3-8b", "--smoke",
                "--requests", "12", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"]
    serve_mod.main()
