"""Paper §III benchmark problem: batches of periodic 1-D diffusion equations
integrated with Crank-Nicolson for 1000 steps (Fig. 2 setting), on all three
backends, checked against the analytic solution.

    PYTHONPATH=src python examples/diffusion_1d.py [--steps 1000] [--n 256]
        [--m 512]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.pde import DiffusionCN

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=1000)
ap.add_argument("--n", type=int, default=256)
ap.add_argument("--m", type=int, default=512)
args = ap.parse_args()

N, M, steps = args.n, args.m, args.steps
dt = 1e-6
x = np.arange(N) / N
f0 = jnp.asarray(np.tile(np.sin(2 * np.pi * x)[:, None], (1, M))
                 .astype(np.float32))

print(f"diffusion: N={N} M={M} steps={steps} (paper Fig. 2 problem)")
for backend in ["core", "fused"]:
    model = DiffusionCN(n=N, dt=dt, backend=backend)
    if backend == "core":
        run = jax.jit(lambda f: model.run(f, steps))
    else:
        def run(f):
            _, step = model.step_fn()
            for _ in range(steps):
                f = step(f)
            return f
    out = np.asarray(jax.block_until_ready(run(f0)))  # includes compile
    t0 = time.time()
    out = np.asarray(jax.block_until_ready(run(f0)))
    dt_wall = time.time() - t0
    want = model.analytic(x, dt * steps)[:, None]
    err = np.max(np.abs(out - want))
    print(f"  backend={backend:6s} {dt_wall:7.2f} s for {steps} steps "
          f"({steps/dt_wall:7.1f} steps/s)   max err vs analytic {err:.2e}")
print("OK")
