"""End-to-end driver: train the ~130M-param mamba2-130m config for a few
hundred steps on the synthetic stream, with checkpoint/auto-resume and the
fault-tolerance runtime (thin wrapper over repro.launch.train).

    PYTHONPATH=src python examples/train_lm.py            # full ~130M run
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized
"""

import sys

from repro.launch import train as train_mod

if __name__ == "__main__":
    if "--quick" in sys.argv:
        sys.argv = [sys.argv[0], "--arch", "mamba2-130m", "--smoke",
                    "--steps", "40", "--batch", "4", "--seq", "64",
                    "--ckpt-dir", "artifacts/train_quick"]
    else:
        sys.argv = [sys.argv[0], "--arch", "mamba2-130m",
                    "--steps", "200", "--batch", "2", "--seq", "128",
                    "--ckpt-dir", "artifacts/train_130m",
                    "--ckpt-every", "25", "--log-every", "5"]
    train_mod.main()
