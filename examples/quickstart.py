"""Quickstart: the paper's contribution in 40 lines.

Solve 10,000 periodic tridiagonal systems that share one LHS (the batch-1D-
PDE setting), compare the constant-LHS storage/solve against the per-system
baseline (cuThomasBatch-equivalent), and run the same thing through the
Pallas TPU kernel (interpret mode on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import TridiagOperator, PentaOperator
from repro.core import periodic_thomas_factor
from repro.kernels import thomas_constant

N, M = 512, 10_000
sigma = 0.4

# --- the paper's setting: one LHS (CN diffusion matrix), M interleaved RHS --
rng = np.random.default_rng(0)
rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))

const_op = TridiagOperator.create(-sigma, 1 + 2 * sigma, -sigma, n=N,
                                  mode="constant", periodic=True)
batch_op = TridiagOperator.create(-sigma, 1 + 2 * sigma, -sigma, n=N,
                                  mode="batch", periodic=True, batch=M)

x_const = const_op.solve(rhs)
x_batch = batch_op.solve(rhs)
print("constant vs per-system max |dx|:",
      float(jnp.max(jnp.abs(x_const - x_batch))))

sc = const_op.storage_bytes(rhs_batch=M)
sb = batch_op.storage_bytes(rhs_batch=M)
print(f"LHS storage:  constant {sc['lhs_bytes']/2**10:.1f} KiB   "
      f"batch {sb['lhs_bytes']/2**20:.1f} MiB")
print(f"total (LHS+RHS): {sc['total_bytes']/2**20:.1f} MiB vs "
      f"{sb['total_bytes']/2**20:.1f} MiB  "
      f"-> {100*(1-sc['total_bytes']/sb['total_bytes']):.1f}% saved "
      f"(paper: ~75%)")

# --- pentadiagonal (hyperdiffusion LHS), incl. the uniform variant ----------
pen_c = PentaOperator.create(sigma, -4*sigma, 1+6*sigma, -4*sigma, sigma,
                             n=N, mode="constant", periodic=True)
pen_b = PentaOperator.create(sigma, -4*sigma, 1+6*sigma, -4*sigma, sigma,
                             n=N, mode="batch", periodic=True, batch=M)
pc = pen_c.storage_bytes(rhs_batch=M)["total_bytes"]
pb = pen_b.storage_bytes(rhs_batch=M)["total_bytes"]
print(f"penta total: {pc/2**20:.1f} MiB vs {pb/2**20:.1f} MiB "
      f"-> {100*(1-pc/pb):.1f}% saved (paper: ~83%)")

# --- the Pallas TPU kernel (interpret=True on CPU) ---------------------------
pf = periodic_thomas_factor(jnp.full((N,), -sigma),
                            jnp.full((N,), 1 + 2 * sigma),
                            jnp.full((N,), -sigma))
y = thomas_constant(pf.factor, rhs[:, :256])
corr = (y[0] + pf.v_last * y[-1]) * pf.inv_denom_sm
x_kernel = y - corr * pf.z[:, None]
print("Pallas kernel vs core max |dx|:",
      float(jnp.max(jnp.abs(x_kernel - x_const[:, :256]))))
print("OK")
