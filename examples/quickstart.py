"""Quickstart: the paper's contribution through the repro.solver front-end.

Solve 10,000 periodic tridiagonal systems that share one LHS (the batch-1D-
PDE setting).  The canonical API is the transformation-native pure pair —
``factorize(system) -> Factorization`` (a pytree) and ``solve(fact, rhs)``
(jittable, vmappable, differentiable) — with ``plan(...)`` as a stateful
shim.  Both retarget across the backend registry:

  * ``reference`` — pure-JAX scan sweeps (the portable oracle),
  * ``pallas``    — the interleaved TPU kernels (interpret mode on CPU),
  * ``sharded``   — systems sharded over a device mesh, LHS replicated,
    each device running the engine's Pallas kernels on its local slice,
  * ``auto``      — pallas when the working set fits VMEM, else reference.

This file is the runnable superset of the README quickstart block (CI
executes both).

``mode="constant"`` vs ``mode="batch"`` is the paper's storage comparison
(cuThomasConstantBatch vs cuThomasBatch).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.solver import (BandedSystem, available_backends, factorize, plan,
                          solve)

N, M = 512, 10_000
sigma = 0.4

rng = np.random.default_rng(0)
rhs = jnp.asarray(rng.normal(size=(N, M)).astype(np.float32))

# --- one spec, every backend ------------------------------------------------
system = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=N,
                              periodic=True, mode="constant")
print("registered backends:", available_backends())

p_ref = plan(system, backend="reference")
x_ref = p_ref.solve(rhs)

p_auto = plan(system, backend="auto")
print(f"backend='auto' resolved to: {p_auto.backend} "
      f"(block_m={getattr(p_auto.impl, 'block_m', 'n/a')})")
x_auto = p_auto.solve(rhs[:, :256])          # interpret mode: keep it small
print("auto vs reference max |dx|:",
      float(jnp.max(jnp.abs(x_auto - x_ref[:, :256]))))

# --- the paper's storage claim: constant vs per-system LHS ------------------
batch_sys = BandedSystem.tridiag(-sigma, 1 + 2 * sigma, -sigma, n=N,
                                 periodic=True, mode="batch", batch=M)
p_batch = plan(batch_sys, backend="reference")
x_batch = p_batch.solve(rhs)
print("constant vs per-system max |dx|:",
      float(jnp.max(jnp.abs(x_ref - x_batch))))

sc = p_ref.storage_bytes(rhs_batch=M)
sb = p_batch.storage_bytes(rhs_batch=M)
print(f"LHS storage:  constant {sc['lhs_bytes']/2**10:.1f} KiB   "
      f"batch {sb['lhs_bytes']/2**20:.1f} MiB")
print(f"total (LHS+RHS): {sc['total_bytes']/2**20:.1f} MiB vs "
      f"{sb['total_bytes']/2**20:.1f} MiB  "
      f"-> {100*(1-sc['total_bytes']/sb['total_bytes']):.1f}% saved "
      f"(paper: ~75%)")

# --- pentadiagonal (hyperdiffusion LHS), incl. the uniform variant ----------
pen = (sigma, -4 * sigma, 1 + 6 * sigma, -4 * sigma, sigma)
pc = plan(BandedSystem.penta(*pen, n=N, periodic=True, mode="constant"),
          backend="reference").storage_bytes(rhs_batch=M)["total_bytes"]
pb = plan(BandedSystem.penta(*pen, n=N, periodic=True, mode="batch", batch=M),
          backend="reference").storage_bytes(rhs_batch=M)["total_bytes"]
print(f"penta total: {pc/2**20:.1f} MiB vs {pb/2**20:.1f} MiB "
      f"-> {100*(1-pc/pb):.1f}% saved (paper: ~83%)")

# --- the sharded backend: LHS replicated per device, systems sharded --------
# Each shard runs the sweep engine's Pallas kernels on its local slice
# (per-device tuned block_m/block_n; kernels="reference" would keep the
# old scan sweeps inside shard_map).
p_sh = plan(system, backend="sharded")
x_sh = p_sh.solve(rhs)
print(f"sharded ({p_sh.impl.n_shards} shard(s), per-shard "
      f"kernels={p_sh.impl.kernels}, block_m={p_sh.impl.block_m}) "
      f"vs reference max |dx|:",
      float(jnp.max(jnp.abs(x_sh - x_ref))))

# --- transformation-native: factor ONCE, scan a whole time loop -------------
# The Factorization is a pytree: it crosses jit/vmap/grad/lax.scan, so a CN
# diffusion loop factors once and runs every step inside ONE compiled program.
sigma_dt = 0.4
fact = factorize(BandedSystem.tridiag(-sigma_dt, 1 + 2 * sigma_dt, -sigma_dt,
                                      n=N, periodic=True),
                 backend="reference")
field0 = rhs[:, :128]


def cn_step(field, _):
    lap = jnp.roll(field, 1, 0) - 2 * field + jnp.roll(field, -1, 0)
    return solve(fact, field + sigma_dt * lap), None


final, _ = jax.lax.scan(cn_step, field0, None, length=1000)
print(f"scanned 1000 CN steps over one factorization: field "
      f"{field0.shape} -> max|C| = {float(jnp.max(jnp.abs(final))):.3e}")

# --- differentiable: the adjoint solve reuses the SAME stored factor --------
grad_rhs = jax.grad(lambda r: jnp.sum(solve(fact, r) ** 2))(field0)
print("grad through solve (transposed solve on the forward factor):",
      f"|g| max = {float(jnp.max(jnp.abs(grad_rhs))):.3e}")
print("OK")
