"""2-D ADI heat equation (the paper's §I motivating application): each ADI
half-step is a batch of 1-D periodic tridiagonal solves sharing one LHS.

    PYTHONPATH=src python examples/adi_2d.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.pde import ADI2D

NX = NY = 128
steps = 200
dt = 5e-6

model = ADI2D(nx=NX, ny=NY, dt=dt)
x = (np.arange(NX) / NX)[:, None]
y = (np.arange(NY) / NY)[None, :]
f0 = jnp.asarray((np.sin(2 * np.pi * x) * np.sin(2 * np.pi * y))
                 .astype(np.float32))

run = jax.jit(lambda f: model.run(f, steps))
jax.block_until_ready(run(f0))
t0 = time.time()
out = np.asarray(jax.block_until_ready(run(f0)))
wall = time.time() - t0

want = model.analytic(x, y, dt * steps).astype(np.float32)
err = np.max(np.abs(out - want))
print(f"ADI 2D: {NX}x{NY}, {steps} steps in {wall:.2f}s "
      f"({steps/wall:.1f} steps/s)")
print(f"max err vs analytic: {err:.2e}")
assert err < 5e-3
print("OK")
